"""RME compaction Pallas kernel — assemble/evaluate on TPU.

The masking crossbar of the paper's RME has no lane-shuffle analogue on TPU;
the idiomatic equivalent is *sort-based compaction*: a stable argsort on the
inverted mask moves surviving records to the front in original order, in one
vectorized pass.  The kernel fuses: score -> predicate -> compaction ->
gather, producing a statically shaped packed block (the commit buffer) plus
a survivor count — this is Bboxcal (paper Fig. 2c) end to end, and the same
configuration drives MoE token dispatch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _evaluate_kernel(x_ref, thr_ref, o_ref, idx_ref, cnt_ref, *,
                     cmp: str, score_index: int, capacity: int):
    x = x_ref[...]                       # (N, D)
    n = x.shape[0]
    thr = thr_ref[0]
    # compare at the promoted dtype (matches rme.evaluate's weak-typed
    # python-float threshold: int records compare in float, not truncated)
    scores = x[:, score_index].astype(thr.dtype)
    mask = {
        "ge": scores >= thr, "gt": scores > thr,
        "le": scores <= thr, "lt": scores < thr,
    }[cmp]
    # stable sort: survivors first, original order preserved
    order = jnp.argsort(jnp.where(mask, 0, 1), stable=True).astype(jnp.int32)
    cnt = jnp.sum(mask.astype(jnp.int32))
    take = order[:capacity]
    rows = jnp.take(x, take, axis=0)
    live = (jnp.arange(capacity) < cnt)
    o_ref[...] = jnp.where(live[:, None], rows, jnp.zeros_like(rows))
    idx_ref[...] = jnp.where(live, take, n).astype(jnp.int32)
    cnt_ref[...] = jnp.minimum(cnt, capacity).reshape(1)


def evaluate(x: jnp.ndarray, threshold, capacity: int, *, cmp: str = "ge",
             score_index: int = 0, interpret: bool = True):
    """Threshold-filter rows of (N, D) -> packed (capacity, D) + idx + count."""
    N, D = x.shape
    kern = functools.partial(_evaluate_kernel, cmp=cmp,
                             score_index=score_index, capacity=capacity)
    thr = jnp.asarray([threshold], dtype=jnp.result_type(x.dtype, threshold))
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[pl.BlockSpec((N, D), lambda i: (0, 0)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=[
            pl.BlockSpec((capacity, D), lambda i: (0, 0)),
            pl.BlockSpec((capacity,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((capacity, D), x.dtype),
            jax.ShapeDtypeStruct((capacity,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(x, thr)


def _evaluate_batched_kernel(x_ref, thr_ref, o_ref, idx_ref, cnt_ref, *,
                             cmp: str, score_index: int, capacity: int):
    # one grid step = one record stream of the batch (block (1, N, D))
    x = x_ref[0]
    n = x.shape[0]
    thr = thr_ref[0]
    scores = x[:, score_index].astype(thr.dtype)  # promoted compare (see
    #                                               _evaluate_kernel)
    mask = {
        "ge": scores >= thr, "gt": scores > thr,
        "le": scores <= thr, "lt": scores < thr,
    }[cmp]
    order = jnp.argsort(jnp.where(mask, 0, 1), stable=True).astype(jnp.int32)
    cnt = jnp.sum(mask.astype(jnp.int32))
    take = order[:capacity]
    rows = jnp.take(x, take, axis=0)
    live = (jnp.arange(capacity) < cnt)
    o_ref[0] = jnp.where(live[:, None], rows, jnp.zeros_like(rows))
    idx_ref[0] = jnp.where(live, take, n).astype(jnp.int32)
    cnt_ref[...] = jnp.minimum(cnt, capacity).reshape(1, 1)


def evaluate_batched(x: jnp.ndarray, threshold, capacity: int, *,
                     cmp: str = "ge", score_index: int = 0,
                     interpret: bool = True):
    """Batched evaluate: (B, N, D) -> (B, capacity, D) + idx + counts.

    The compaction grid is lifted over the leading axis — one grid step per
    record stream, each an independent sort-based compaction (the paper's
    RME run once per stream, exactly like the unbatched kernel B times but
    in one launch)."""
    B, N, D = x.shape
    kern = functools.partial(_evaluate_batched_kernel, cmp=cmp,
                             score_index=score_index, capacity=capacity)
    thr = jnp.asarray([threshold], dtype=jnp.result_type(x.dtype, threshold))
    return pl.pallas_call(
        kern,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, N, D), lambda b: (b, 0, 0)),
                  pl.BlockSpec((1,), lambda b: (0,))],
        out_specs=[
            pl.BlockSpec((1, capacity, D), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, capacity), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, capacity, D), x.dtype),
            jax.ShapeDtypeStruct((B, capacity), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x, thr)


def _evaluate_chain_kernel(*refs, cmp: str, score_index: int, capacity: int,
                           has_mask: bool, fill: float):
    """Chained evaluate: the record stream is gathered from the chain input
    slab (coarse pre-links pulled back to the stream grid) and compacted in
    the same pass — the producer's output never exists outside VMEM."""
    if has_mask:
        x_ref, idx_ref, ok_ref, thr_ref, o_ref, idx_out_ref, cnt_ref = refs
    else:
        x_ref, idx_ref, thr_ref, o_ref, idx_out_ref, cnt_ref = refs
    idx = idx_ref[0]                      # (N, D) pullback into the slab
    x = jnp.take(x_ref[...], idx.reshape(-1)).reshape(idx.shape)
    if has_mask:
        x = jnp.where(ok_ref[0], x, jnp.asarray(fill, dtype=x.dtype))
    n = x.shape[0]
    thr = thr_ref[0]
    scores = x[:, score_index].astype(thr.dtype)
    mask = {
        "ge": scores >= thr, "gt": scores > thr,
        "le": scores <= thr, "lt": scores < thr,
    }[cmp]
    order = jnp.argsort(jnp.where(mask, 0, 1), stable=True).astype(jnp.int32)
    cnt = jnp.sum(mask.astype(jnp.int32))
    take = order[:capacity]
    rows = jnp.take(x, take, axis=0)
    live = (jnp.arange(capacity) < cnt)
    o_ref[0] = jnp.where(live[:, None], rows, jnp.zeros_like(rows))
    idx_out_ref[0] = jnp.where(live, take, n).astype(jnp.int32)
    cnt_ref[...] = jnp.minimum(cnt, capacity).reshape(1, 1)


def evaluate_chained(x_slab: jnp.ndarray, idx: jnp.ndarray,
                     ok: jnp.ndarray | None, fill: float, threshold,
                     capacity: int, *, cmp: str = "ge", score_index: int = 0,
                     interpret: bool = True):
    """Batched evaluate fed through a coarse pullback: ``idx``/``ok`` are
    (B, N, D) constants mapping each stream element into the flat chain
    input ``x_slab``; one grid step gathers + compacts one stream."""
    B, N, D = idx.shape
    kern = functools.partial(
        _evaluate_chain_kernel, cmp=cmp, score_index=score_index,
        capacity=capacity, has_mask=ok is not None, fill=fill)
    thr = jnp.asarray([threshold],
                      dtype=jnp.result_type(x_slab.dtype, threshold))
    xf = x_slab.reshape(-1)
    in_specs = [pl.BlockSpec((xf.size,), lambda b: (0,)),
                pl.BlockSpec((1, N, D), lambda b: (b, 0, 0))]
    args = [xf, idx]
    if ok is not None:
        in_specs.append(pl.BlockSpec((1, N, D), lambda b: (b, 0, 0)))
        args.append(ok)
    in_specs.append(pl.BlockSpec((1,), lambda b: (0,)))
    args.append(thr)
    return pl.pallas_call(
        kern,
        grid=(B,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, capacity, D), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, capacity), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, capacity, D), x_slab.dtype),
            jax.ShapeDtypeStruct((B, capacity), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=interpret,
    )(*args)


def _assemble_kernel(x_ref, mask_ref, o_ref, cnt_ref, *, capacity: int):
    x = x_ref[...]
    mask = mask_ref[...] != 0
    order = jnp.argsort(jnp.where(mask, 0, 1), stable=True).astype(jnp.int32)
    cnt = jnp.sum(mask.astype(jnp.int32))
    rows = jnp.take(x, order[:capacity], axis=0)
    live = (jnp.arange(capacity) < cnt)
    o_ref[...] = jnp.where(live[:, None], rows, jnp.zeros_like(rows))
    cnt_ref[...] = jnp.minimum(cnt, capacity).reshape(1)


def _assemble_batched_kernel(x_ref, mask_ref, o_ref, cnt_ref, *,
                             capacity: int):
    x = x_ref[0]
    mask = mask_ref[0] != 0
    order = jnp.argsort(jnp.where(mask, 0, 1), stable=True).astype(jnp.int32)
    cnt = jnp.sum(mask.astype(jnp.int32))
    rows = jnp.take(x, order[:capacity], axis=0)
    live = (jnp.arange(capacity) < cnt)
    o_ref[0] = jnp.where(live[:, None], rows, jnp.zeros_like(rows))
    cnt_ref[...] = jnp.minimum(cnt, capacity).reshape(1, 1)


def assemble_batched(x: jnp.ndarray, mask: jnp.ndarray, capacity: int, *,
                     interpret: bool = True):
    """Batched assemble: (B, N, D) + (B, N) mask -> (B, capacity, D) + counts."""
    B, N, D = x.shape
    kern = functools.partial(_assemble_batched_kernel, capacity=capacity)
    return pl.pallas_call(
        kern,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, N, D), lambda b: (b, 0, 0)),
                  pl.BlockSpec((1, N), lambda b: (b, 0))],
        out_specs=[
            pl.BlockSpec((1, capacity, D), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, capacity, D), x.dtype),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x, mask.astype(jnp.int32))


def assemble(x: jnp.ndarray, mask: jnp.ndarray, capacity: int, *,
             interpret: bool = True):
    """Pack rows of (N, D) selected by a runtime mask -> (capacity, D) + count."""
    N, D = x.shape
    kern = functools.partial(_assemble_kernel, capacity=capacity)
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[pl.BlockSpec((N, D), lambda i: (0, 0)),
                  pl.BlockSpec((N,), lambda i: (0,))],
        out_specs=[
            pl.BlockSpec((capacity, D), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((capacity, D), x.dtype),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(x, mask.astype(jnp.int32))
