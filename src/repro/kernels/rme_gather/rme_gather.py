"""RME compaction Pallas kernel — assemble/evaluate on TPU.

The masking crossbar of the paper's RME has no lane-shuffle analogue on TPU;
the idiomatic equivalent is *sort-based compaction*: a stable argsort on the
inverted mask moves surviving records to the front in original order, in one
vectorized pass.  The kernel fuses: score -> predicate -> compaction ->
gather, producing a statically shaped packed block (the commit buffer) plus
a survivor count — this is Bboxcal (paper Fig. 2c) end to end, and the same
configuration drives MoE token dispatch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _evaluate_kernel(x_ref, thr_ref, o_ref, idx_ref, cnt_ref, *,
                     cmp: str, score_index: int, capacity: int):
    x = x_ref[...]                       # (N, D)
    n = x.shape[0]
    scores = x[:, score_index]
    thr = thr_ref[0]
    mask = {
        "ge": scores >= thr, "gt": scores > thr,
        "le": scores <= thr, "lt": scores < thr,
    }[cmp]
    # stable sort: survivors first, original order preserved
    order = jnp.argsort(jnp.where(mask, 0, 1), stable=True).astype(jnp.int32)
    cnt = jnp.sum(mask.astype(jnp.int32))
    take = order[:capacity]
    rows = jnp.take(x, take, axis=0)
    live = (jnp.arange(capacity) < cnt)
    o_ref[...] = jnp.where(live[:, None], rows, jnp.zeros_like(rows))
    idx_ref[...] = jnp.where(live, take, n).astype(jnp.int32)
    cnt_ref[...] = jnp.minimum(cnt, capacity).reshape(1)


def evaluate(x: jnp.ndarray, threshold, capacity: int, *, cmp: str = "ge",
             score_index: int = 0, interpret: bool = True):
    """Threshold-filter rows of (N, D) -> packed (capacity, D) + idx + count."""
    N, D = x.shape
    kern = functools.partial(_evaluate_kernel, cmp=cmp,
                             score_index=score_index, capacity=capacity)
    thr = jnp.asarray([threshold], dtype=x.dtype)
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[pl.BlockSpec((N, D), lambda i: (0, 0)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=[
            pl.BlockSpec((capacity, D), lambda i: (0, 0)),
            pl.BlockSpec((capacity,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((capacity, D), x.dtype),
            jax.ShapeDtypeStruct((capacity,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(x, thr)


def _assemble_kernel(x_ref, mask_ref, o_ref, cnt_ref, *, capacity: int):
    x = x_ref[...]
    mask = mask_ref[...] != 0
    order = jnp.argsort(jnp.where(mask, 0, 1), stable=True).astype(jnp.int32)
    cnt = jnp.sum(mask.astype(jnp.int32))
    rows = jnp.take(x, order[:capacity], axis=0)
    live = (jnp.arange(capacity) < cnt)
    o_ref[...] = jnp.where(live[:, None], rows, jnp.zeros_like(rows))
    cnt_ref[...] = jnp.minimum(cnt, capacity).reshape(1)


def assemble(x: jnp.ndarray, mask: jnp.ndarray, capacity: int, *,
             interpret: bool = True):
    """Pack rows of (N, D) selected by a runtime mask -> (capacity, D) + count."""
    N, D = x.shape
    kern = functools.partial(_assemble_kernel, capacity=capacity)
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[pl.BlockSpec((N, D), lambda i: (0, 0)),
                  pl.BlockSpec((N,), lambda i: (0,))],
        out_specs=[
            pl.BlockSpec((capacity, D), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((capacity, D), x.dtype),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(x, mask.astype(jnp.int32))
