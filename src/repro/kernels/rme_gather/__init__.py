from repro.kernels.rme_gather.ops import assemble_call, evaluate_call  # noqa: F401
from repro.kernels.rme_gather.ref import assemble_ref, evaluate_ref  # noqa: F401
