"""Pure-jnp oracle: the core RME implementation."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import rme


def evaluate_ref(x, threshold, capacity, *, cmp="ge", score_index=0):
    rows, idx, cnt = rme.evaluate(x, threshold, capacity, cmp=cmp,
                                  score_index=score_index)
    return rows, idx, jnp.reshape(cnt, (1,))


def assemble_ref(x, mask, capacity):
    rows, cnt = rme.assemble(x, mask, capacity)
    return rows, jnp.reshape(cnt, (1,))
