"""Jit'd wrappers for the RME compaction kernels + dispatch registration."""

import math
from functools import partial

import jax

from repro.core.dispatch import register_rule
from repro.core.instr import TMOpcode
from repro.kernels.rme_gather.rme_gather import (assemble, assemble_batched,
                                                 evaluate, evaluate_batched)


@partial(jax.jit, static_argnames=("capacity", "cmp", "score_index", "interpret"))
def evaluate_call(x, threshold, *, capacity, cmp="ge", score_index=0,
                  interpret=True):
    return evaluate(x, threshold, capacity, cmp=cmp, score_index=score_index,
                    interpret=interpret)


@partial(jax.jit, static_argnames=("capacity", "interpret"))
def assemble_call(x, mask, *, capacity, interpret=True):
    return assemble(x, mask, capacity, interpret=interpret)


@partial(jax.jit, static_argnames=("capacity", "cmp", "score_index", "interpret"))
def evaluate_batched_call(x, threshold, *, capacity, cmp="ge", score_index=0,
                          interpret=True):
    """(…, N, D) record streams: leading axes flatten onto the kernel grid."""
    batch = x.shape[:-2]
    rows, idx, cnt = evaluate_batched(
        x.reshape((-1,) + x.shape[-2:]), threshold, capacity, cmp=cmp,
        score_index=score_index, interpret=interpret)
    return (rows.reshape(batch + rows.shape[1:]),
            idx.reshape(batch + idx.shape[1:]),
            cnt.reshape(batch))


@partial(jax.jit, static_argnames=("capacity", "interpret"))
def assemble_batched_call(x, mask, *, capacity, interpret=True):
    batch = x.shape[:-2]
    packed, cnt = assemble_batched(
        x.reshape((-1,) + x.shape[-2:]), mask.reshape((-1,) + mask.shape[-1:]),
        capacity, interpret=interpret)
    return packed.reshape(batch + packed.shape[1:]), cnt.reshape(batch)


# ---------------------------------------------------------------------------
# dispatch-registry rules: FINE instructions whose RME config the sort-based
# compaction kernel supports (runtime predicate/mask, static capacity, record
# streams with any number of leading batch axes — the batched kernels lift
# the compaction grid over them).  Static lane masks and top-k fall back.
# ---------------------------------------------------------------------------

def _evaluate_matches(ins, srcs, batch_dims, segment_bytes=None):
    if ins.opcode != TMOpcode.FINE_EVALUATE:
        return None
    cfg = ins.rme
    if cfg.top_k is not None or cfg.capacity is None or cfg.threshold is None:
        return None
    if len(srcs) != 1 or srcs[0].ndim != batch_dims + 2:
        return None
    return "pallas.rme.evaluate"


def _evaluate_run(ins, srcs, batch_dims, interpret, segment_bytes=None):
    if batch_dims == 0:
        rows, _, _ = evaluate_call(srcs[0], ins.rme.threshold,
                                   capacity=ins.rme.capacity, cmp=ins.rme.cmp,
                                   score_index=ins.rme.score_index,
                                   interpret=interpret)
        return rows
    rows, _, _ = evaluate_batched_call(
        srcs[0], ins.rme.threshold, capacity=ins.rme.capacity,
        cmp=ins.rme.cmp, score_index=ins.rme.score_index, interpret=interpret)
    return rows


def _assemble_matches(ins, srcs, batch_dims, segment_bytes=None):
    if ins.opcode != TMOpcode.FINE_ASSEMBLE:
        return None
    cfg = ins.rme
    if cfg.lane_mask is not None or cfg.capacity is None:
        return None
    if len(srcs) != 2 or srcs[0].ndim != batch_dims + 2 \
            or srcs[1].ndim != batch_dims + 1:
        return None
    if srcs[0].shape[:-1] != srcs[1].shape:
        return None
    return "pallas.rme.assemble"


def _assemble_run(ins, srcs, batch_dims, interpret, segment_bytes=None):
    if batch_dims == 0:
        packed, _ = assemble_call(srcs[0], srcs[1],
                                  capacity=ins.rme.capacity,
                                  interpret=interpret)
        return packed
    packed, _ = assemble_batched_call(srcs[0], srcs[1],
                                      capacity=ins.rme.capacity,
                                      interpret=interpret)
    return packed


def _rme_segments(ins, srcs, batch_dims, segment_bytes=None):
    # one grid step per record stream (the batched kernels' grid)
    return max(1, math.prod(srcs[0].shape[:batch_dims]))


register_rule("rme_gather.evaluate", _evaluate_matches, _evaluate_run,
              priority=10, segments=_rme_segments)
register_rule("rme_gather.assemble", _assemble_matches, _assemble_run,
              priority=10, segments=_rme_segments)
