"""Jit'd wrappers for the RME compaction kernels + dispatch registration."""

import math
from functools import lru_cache, partial

import jax

from repro.core.dispatch import register_chain_rule, register_rule
from repro.core.instr import TMOpcode
from repro.kernels.rme_gather.rme_gather import (assemble, assemble_batched,
                                                 evaluate, evaluate_batched,
                                                 evaluate_chained)


@partial(jax.jit, static_argnames=("capacity", "cmp", "score_index", "interpret"))
def evaluate_call(x, threshold, *, capacity, cmp="ge", score_index=0,
                  interpret=True):
    return evaluate(x, threshold, capacity, cmp=cmp, score_index=score_index,
                    interpret=interpret)


@partial(jax.jit, static_argnames=("capacity", "interpret"))
def assemble_call(x, mask, *, capacity, interpret=True):
    return assemble(x, mask, capacity, interpret=interpret)


@partial(jax.jit, static_argnames=("capacity", "cmp", "score_index", "interpret"))
def evaluate_batched_call(x, threshold, *, capacity, cmp="ge", score_index=0,
                          interpret=True):
    """(…, N, D) record streams: leading axes flatten onto the kernel grid."""
    batch = x.shape[:-2]
    rows, idx, cnt = evaluate_batched(
        x.reshape((-1,) + x.shape[-2:]), threshold, capacity, cmp=cmp,
        score_index=score_index, interpret=interpret)
    return (rows.reshape(batch + rows.shape[1:]),
            idx.reshape(batch + idx.shape[1:]),
            cnt.reshape(batch))


@partial(jax.jit, static_argnames=("capacity", "interpret"))
def assemble_batched_call(x, mask, *, capacity, interpret=True):
    batch = x.shape[:-2]
    packed, cnt = assemble_batched(
        x.reshape((-1,) + x.shape[-2:]), mask.reshape((-1,) + mask.shape[-1:]),
        capacity, interpret=interpret)
    return packed.reshape(batch + packed.shape[1:]), cnt.reshape(batch)


# ---------------------------------------------------------------------------
# dispatch-registry rules: FINE instructions whose RME config the sort-based
# compaction kernel supports (runtime predicate/mask, static capacity, record
# streams with any number of leading batch axes — the batched kernels lift
# the compaction grid over them).  Static lane masks and top-k fall back.
# ---------------------------------------------------------------------------

def _evaluate_matches(ins, srcs, batch_dims, segment_bytes=None):
    if ins.opcode != TMOpcode.FINE_EVALUATE:
        return None
    cfg = ins.rme
    if cfg.top_k is not None or cfg.capacity is None or cfg.threshold is None:
        return None
    if len(srcs) != 1 or srcs[0].ndim != batch_dims + 2:
        return None
    return "pallas.rme.evaluate"


def _evaluate_run(ins, srcs, batch_dims, interpret, segment_bytes=None):
    if batch_dims == 0:
        rows, _, _ = evaluate_call(srcs[0], ins.rme.threshold,
                                   capacity=ins.rme.capacity, cmp=ins.rme.cmp,
                                   score_index=ins.rme.score_index,
                                   interpret=interpret)
        return rows
    rows, _, _ = evaluate_batched_call(
        srcs[0], ins.rme.threshold, capacity=ins.rme.capacity,
        cmp=ins.rme.cmp, score_index=ins.rme.score_index, interpret=interpret)
    return rows


def _assemble_matches(ins, srcs, batch_dims, segment_bytes=None):
    if ins.opcode != TMOpcode.FINE_ASSEMBLE:
        return None
    cfg = ins.rme
    if cfg.lane_mask is not None or cfg.capacity is None:
        return None
    if len(srcs) != 2 or srcs[0].ndim != batch_dims + 2 \
            or srcs[1].ndim != batch_dims + 1:
        return None
    if srcs[0].shape[:-1] != srcs[1].shape:
        return None
    return "pallas.rme.assemble"


def _assemble_run(ins, srcs, batch_dims, interpret, segment_bytes=None):
    if batch_dims == 0:
        packed, _ = assemble_call(srcs[0], srcs[1],
                                  capacity=ins.rme.capacity,
                                  interpret=interpret)
        return packed
    packed, _ = assemble_batched_call(srcs[0], srcs[1],
                                      capacity=ins.rme.capacity,
                                      interpret=interpret)
    return packed


def _rme_segments(ins, srcs, batch_dims, segment_bytes=None):
    # one grid step per record stream (the batched kernels' grid)
    return max(1, math.prod(srcs[0].shape[:batch_dims]))


# ---------------------------------------------------------------------------
# chain rule: coarse pre-links pulled back into the evaluate kernel's load —
# the record stream is gathered from the chain input slab and compacted in
# one launch (detect tails: layout Rearrange/reshape + Bboxcal as one kernel)
# ---------------------------------------------------------------------------

def _chain_eval_maps(instrs, srcs, batch_dims):
    """Lifted pre-link maps + the FINE link's stream rank, or (None, 0)."""
    from repro.core.affine import batch_extend_map
    last = instrs[-1]
    if last.opcode != TMOpcode.FINE_EVALUATE:
        return None, 0
    cfg = last.rme
    if cfg.top_k is not None or cfg.capacity is None or cfg.threshold is None:
        return None, 0
    if len(last.srcs) != 1 or srcs[-1][0] is not None:
        return None, 0
    x = srcs[0][0]
    if x is None:
        return None, 0
    batch = x.shape[:batch_dims]
    maps = []
    for k, ins in enumerate(instrs[:-1]):
        if ins.opcode != TMOpcode.COARSE or ins.map_ is None \
                or ins.ew is not None or len(ins.srcs) != 1:
            return None, 0
        if k > 0 and srcs[k][0] is not None:
            return None, 0
        m = batch_extend_map(ins.map_, batch)
        if k == 0 and x.shape != m.in_shape:
            return None, 0
        if maps and m.in_shape != maps[-1].out_shape:
            return None, 0
        maps.append(m)
    fine_bd = batch_dims + (last.meta or {}).get("batch_dims", 0)
    if len(maps[-1].out_shape) != fine_bd + 2:
        return None, 0
    return tuple(maps), fine_bd


@lru_cache(maxsize=256)
def _chain_eval_pullback(maps):
    """(idx, ok, fill) constants on the stream grid, or None on mixed fills
    (a permanent decline — cached, so repeat executor runs stay cheap)."""
    from repro.kernels.tm_affine.chain import fold_pullback
    try:
        J, OK, fill = fold_pullback(maps)
    except ValueError:
        return None
    stream = maps[-1].out_shape
    N, D = stream[-2], stream[-1]
    idx = jax.numpy.asarray(J.reshape(-1, N, D))
    ok = None if OK is None else jax.numpy.asarray(OK.reshape(-1, N, D))
    return idx, ok, fill


def _chain_eval_lower(instrs, srcs, batch_dims, interpret,
                      segment_bytes=None):
    """Single-pass chained-evaluate lowering, or None."""
    from repro.kernels.tm_affine.chain import CHAIN_VMEM_BUDGET
    maps, _ = _chain_eval_maps(instrs, srcs, batch_dims)
    if maps is None:
        return None
    x = srcs[0][0]
    stream_elems = math.prod(maps[-1].out_shape)
    # the chain slab plus the pullback index/mask constants must stay
    # VMEM-resident for the launch — same legality rule as tm_affine.chain
    if x.size * x.dtype.itemsize + 8 * stream_elems > CHAIN_VMEM_BUDGET:
        return None
    pulled = _chain_eval_pullback(maps)
    if pulled is None:
        return None
    idx, ok, fill = pulled
    cfg = instrs[-1].rme
    stream = maps[-1].out_shape
    rows, _, _ = evaluate_chained_call(
        x, idx, ok, fill, cfg.threshold, capacity=cfg.capacity,
        cmp=cfg.cmp, score_index=cfg.score_index, interpret=interpret)
    val = rows.reshape(stream[:-2] + rows.shape[1:])
    return val, "pallas.chain+rme.evaluate", max(1, math.prod(stream[:-2]))


@partial(jax.jit, static_argnames=("fill", "capacity", "cmp", "score_index",
                                  "interpret"))
def evaluate_chained_call(x, idx, ok, fill, threshold, *, capacity,
                          cmp="ge", score_index=0, interpret=True):
    return evaluate_chained(x, idx, ok, fill, threshold, capacity,
                            cmp=cmp, score_index=score_index,
                            interpret=interpret)


register_rule("rme_gather.evaluate", _evaluate_matches, _evaluate_run,
              priority=10, segments=_rme_segments)
register_rule("rme_gather.assemble", _assemble_matches, _assemble_run,
              priority=10, segments=_rme_segments)
register_chain_rule("rme_gather.chain_evaluate", _chain_eval_lower,
                    priority=10)
