"""Jit'd wrappers for the RME compaction kernels + dispatch registration."""

from functools import partial

import jax

from repro.core.dispatch import register_rule
from repro.core.instr import TMOpcode
from repro.kernels.rme_gather.rme_gather import assemble, evaluate


@partial(jax.jit, static_argnames=("capacity", "cmp", "score_index", "interpret"))
def evaluate_call(x, threshold, *, capacity, cmp="ge", score_index=0,
                  interpret=True):
    return evaluate(x, threshold, capacity, cmp=cmp, score_index=score_index,
                    interpret=interpret)


@partial(jax.jit, static_argnames=("capacity", "interpret"))
def assemble_call(x, mask, *, capacity, interpret=True):
    return assemble(x, mask, capacity, interpret=interpret)


# ---------------------------------------------------------------------------
# dispatch-registry rules: FINE instructions whose RME config the sort-based
# compaction kernel supports (runtime predicate/mask, static capacity, 2-D
# record stream).  Static lane masks and top-k fall back to the engine.
# ---------------------------------------------------------------------------

def _evaluate_matches(ins, srcs, batch_dims):
    if ins.opcode != TMOpcode.FINE_EVALUATE or batch_dims != 0:
        return None
    cfg = ins.rme
    if cfg.top_k is not None or cfg.capacity is None or cfg.threshold is None:
        return None
    if len(srcs) != 1 or srcs[0].ndim != 2:
        return None
    return "pallas.rme.evaluate"


def _evaluate_run(ins, srcs, batch_dims, interpret):
    rows, _, _ = evaluate_call(srcs[0], ins.rme.threshold,
                               capacity=ins.rme.capacity, cmp=ins.rme.cmp,
                               score_index=ins.rme.score_index,
                               interpret=interpret)
    return rows


def _assemble_matches(ins, srcs, batch_dims):
    if ins.opcode != TMOpcode.FINE_ASSEMBLE or batch_dims != 0:
        return None
    cfg = ins.rme
    if cfg.lane_mask is not None or cfg.capacity is None:
        return None
    if len(srcs) != 2 or srcs[0].ndim != 2 or srcs[1].ndim != 1:
        return None
    return "pallas.rme.assemble"


def _assemble_run(ins, srcs, batch_dims, interpret):
    packed, _ = assemble_call(srcs[0], srcs[1],
                              capacity=ins.rme.capacity, interpret=interpret)
    return packed


register_rule("rme_gather.evaluate", _evaluate_matches, _evaluate_run,
              priority=10)
register_rule("rme_gather.assemble", _assemble_matches, _assemble_run,
              priority=10)
