from functools import partial

import jax

from repro.kernels.rme_gather.rme_gather import assemble, evaluate


@partial(jax.jit, static_argnames=("capacity", "cmp", "score_index", "interpret"))
def evaluate_call(x, threshold, *, capacity, cmp="ge", score_index=0,
                  interpret=True):
    return evaluate(x, threshold, capacity, cmp=cmp, score_index=score_index,
                    interpret=interpret)


@partial(jax.jit, static_argnames=("capacity", "interpret"))
def assemble_call(x, mask, *, capacity, interpret=True):
    return assemble(x, mask, capacity, interpret=interpret)
