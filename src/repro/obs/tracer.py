"""Lock-cheap, thread-safe tracing — nested spans, counters, Chrome export.

One :class:`Tracer` is the single timeline of a compile/execute/serve run:

* **spans** — ``with tracer.span("compile/trace"): ...`` measures a nested
  region on the calling thread's track; ``add_span`` records an interval
  whose timestamps were stamped elsewhere (a stream event's realized busy
  interval lands on its *engine's* track, so the trace and the serving
  stats share one source of truth).
* **counters** — ``count`` accumulates (kernel launches, HBM bytes, cache
  hits); ``counter`` samples an absolute value (queue depth).  Both emit
  Chrome ``C`` events, so Perfetto draws them as counter tracks over time.
* **instants** — point markers (a request submit).

Everything records ``time.monotonic()`` seconds — the same clock the stream
runtime stamps events with — and is appended under one lock whose critical
section is a single ``list.append``; the recorded payload is built outside
it.  When tracing is off, the module-level :data:`NULL_TRACER` stands in:
every method is a no-op and ``enabled`` is ``False``, so hot paths guard
per-instruction recording with one attribute check.

``export_chrome_trace(path)`` writes Chrome-trace JSON (the ``traceEvents``
array format): open it at https://ui.perfetto.dev or ``chrome://tracing``.
Tracks (``tid``) are one per engine/stream/thread, named via ``M``
(metadata) events; spans are complete (``X``) events with microsecond
``ts``/``dur`` relative to the tracer's epoch.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Any, Callable

__all__ = ["SpanRecord", "Tracer", "NullTracer", "NULL_TRACER", "as_tracer"]


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One completed span: a named interval on a track."""

    name: str
    track: str                 # engine / stream / thread the span ran on
    t_start: float             # time.monotonic() seconds
    t_end: float
    depth: int = 0             # nesting depth at open (0 = top level)
    args: tuple = ()           # ((key, value), ...) — JSON-safe payload
    overlap_ok: bool = False   # concurrent-lifetime span (request windows):
    # exempt from stack discipline, exported as an async b/e pair

    @property
    def duration_s(self) -> float:
        return self.t_end - self.t_start

    def arg(self, key: str, default=None):
        for k, v in self.args:
            if k == key:
                return v
        return default


class _Span:
    """Context manager returned by :meth:`Tracer.span`."""

    __slots__ = ("_tracer", "name", "track", "_args", "t_start", "t_end",
                 "_depth")

    def __init__(self, tracer: "Tracer", name: str, track: str | None,
                 args: dict):
        self._tracer = tracer
        self.name = name
        self.track = track
        self._args = args
        self.t_start = 0.0
        self.t_end = 0.0
        self._depth = 0

    def set(self, **args) -> "_Span":
        """Attach args mid-span (stage reports produced inside the region)."""
        self._args.update(args)
        return self

    def __enter__(self) -> "_Span":
        tracer = self._tracer
        stack = tracer._stack()
        if self.track is None:
            # inherit the enclosing span's track so a nested stage stays on
            # its parent's lane; top-level spans land on the thread's track
            self.track = (stack[-1].track if stack
                          else threading.current_thread().name)
        self._depth = len(stack)
        stack.append(self)
        self.t_start = tracer._clock()
        return self

    def __exit__(self, *exc) -> bool:
        tracer = self._tracer
        self.t_end = tracer._clock()
        stack = tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        tracer._record((self.name, self.track, self.t_start, self.t_end,
                        self._depth, tuple(self._args.items()), False))
        return False


class Tracer:
    """Thread-safe span/counter/instant recorder with Chrome-trace export.

    ``detail`` picks the recording granularity: ``"phase"`` (default) spans
    compile stages, phases, requests and stream intervals; ``"instr"``
    additionally records per-TM-instruction and per-chain spans inside every
    TMU phase — a much denser timeline, for drilling into one program rather
    than watching a serving run."""

    enabled = True
    DETAILS = ("phase", "instr")

    def __init__(self, clock: Callable[[], float] = time.monotonic,
                 detail: str = "phase"):
        if detail not in self.DETAILS:
            raise ValueError(f"unknown detail {detail!r}; "
                             f"expected one of {self.DETAILS}")
        self.detail = detail
        self._clock = clock
        self.t0 = clock()
        self._lock = threading.Lock()
        # raw span tuples (SpanRecord field order) — building the frozen
        # dataclass on record costs ~5x the append, so the hot path stores
        # tuples and ``spans()`` materializes records lazily
        self._spans: list[tuple] = []
        self._instants: list[tuple] = []        # (name, track, t, args)
        self._counter_events: list[tuple] = []  # (name, track, t, value)
        self._counters: dict[str, float] = {}   # cumulative totals
        self._tls = threading.local()

    # --- recording --------------------------------------------------------
    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _record(self, rec: tuple) -> None:
        with self._lock:
            self._spans.append(rec)

    def span(self, name: str, track: str | None = None, **args) -> _Span:
        """Open a nested span on this thread (``track=None`` inherits the
        enclosing span's track, else the thread's name)."""
        return _Span(self, name, track, args)

    def add_span(self, name: str, track: str, t_start: float, t_end: float,
                 overlap_ok: bool = False, **args) -> None:
        """Record a completed interval stamped elsewhere (stream events,
        request latencies) — it joins ``track`` without nesting.  Pass
        ``overlap_ok=True`` for intervals with concurrent lifetimes on one
        track (in-flight request windows): they skip the stack-discipline
        check and export as Chrome async events."""
        self._record((name, track, t_start, t_end, 0,
                      tuple(args.items()), overlap_ok))

    def instant(self, name: str, track: str | None = None, **args) -> None:
        t = self._clock()
        if track is None:
            track = threading.current_thread().name
        with self._lock:
            self._instants.append((name, track, t, tuple(args.items())))

    def count(self, name: str, delta: float = 1.0,
              track: str = "counters") -> None:
        """Accumulate ``delta`` into counter ``name`` and emit the running
        total as a counter sample (a rising Perfetto counter track)."""
        t = self._clock()
        with self._lock:
            total = self._counters.get(name, 0.0) + delta
            self._counters[name] = total
            self._counter_events.append((name, track, t, total))

    def counter(self, name: str, value: float,
                track: str = "counters") -> None:
        """Sample an absolute value (queue depth, in-flight jobs)."""
        t = self._clock()
        with self._lock:
            self._counters[name] = value
            self._counter_events.append((name, track, t, value))

    # --- introspection ----------------------------------------------------
    def spans(self, prefix: str | None = None,
              track: str | None = None) -> list[SpanRecord]:
        with self._lock:
            raw = list(self._spans)
        if prefix is not None:
            raw = [t for t in raw if t[0].startswith(prefix)]
        if track is not None:
            raw = [t for t in raw if t[1] == track]
        return [SpanRecord(*t) for t in raw]

    def counters(self) -> dict[str, float]:
        """Final cumulative/sampled value per counter name."""
        with self._lock:
            return dict(self._counters)

    def tracks(self) -> list[str]:
        with self._lock:
            seen: dict[str, None] = {}
            for t in self._spans:
                seen.setdefault(t[1])
            for _, track, _, _ in self._instants:
                seen.setdefault(track)
        return list(seen)

    def nesting_errors(self, eps: float = 1e-9) -> list[str]:
        """Integrity check: no negative durations, and spans on one track
        either nest fully or are disjoint (stack discipline).  Explicit
        ``add_span`` intervals (engine busy intervals) are depth-0 siblings
        and may legitimately abut; only *partial* overlap of a span with an
        enclosing open span is an error."""
        errors = []
        spans = self.spans()
        for s in spans:
            if s.t_end < s.t_start - eps:
                errors.append(f"negative duration: {s.name} on {s.track} "
                              f"({s.t_start}..{s.t_end})")
        by_track: dict[str, list[SpanRecord]] = {}
        for s in spans:
            if not s.overlap_ok:
                by_track.setdefault(s.track, []).append(s)
        for track, ss in by_track.items():
            ss.sort(key=lambda s: (s.t_start, -s.t_end))
            stack: list[SpanRecord] = []
            for s in ss:
                while stack and stack[-1].t_end <= s.t_start + eps:
                    stack.pop()
                if stack and s.t_end > stack[-1].t_end + eps:
                    errors.append(
                        f"partial overlap on {track}: {s.name} "
                        f"({s.t_start:.6f}..{s.t_end:.6f}) escapes "
                        f"{stack[-1].name} (..{stack[-1].t_end:.6f})")
                stack.append(s)
        return errors

    # --- Chrome-trace / Perfetto export -----------------------------------
    def _tid_map(self, tracks: list[str]) -> dict[str, int]:
        # engines first so the TMU/TPU lanes sit at the top of the view
        ordered = sorted(tracks, key=lambda t: (t not in ("tmu", "tpu"), t))
        return {track: i for i, track in enumerate(ordered)}

    def chrome_trace(self) -> dict:
        """The trace as a Chrome-trace dict (``{"traceEvents": [...]}``)."""
        with self._lock:
            spans = list(self._spans)
            instants = list(self._instants)
            counter_events = list(self._counter_events)
        t0 = self.t0
        tracks: dict[str, None] = {}
        for t in spans:
            tracks.setdefault(t[1])
        for _, track, _, _ in instants:
            tracks.setdefault(track)
        for _, track, _, _ in counter_events:
            tracks.setdefault(track)
        tid = self._tid_map(list(tracks))
        events: list[dict] = []
        for track, i in tid.items():
            events.append({"ph": "M", "pid": 1, "tid": i,
                           "name": "thread_name", "args": {"name": track}})
        for i, (name, track, t_start, t_end, _depth, args,
                overlap_ok) in enumerate(spans):
            if overlap_ok:
                # concurrent lifetimes on one track: an async begin/end pair
                # (grouped by cat+id) renders overlap correctly in Perfetto
                common = {"pid": 1, "tid": tid[track], "name": name,
                          "cat": name.split("/", 1)[0], "id": i + 1}
                events.append({**common, "ph": "b",
                               "ts": (t_start - t0) * 1e6,
                               "args": dict(args)})
                events.append({**common, "ph": "e",
                               "ts": (t_end - t0) * 1e6})
                continue
            events.append({"ph": "X", "pid": 1, "tid": tid[track],
                           "name": name, "cat": name.split("/", 1)[0],
                           "ts": (t_start - t0) * 1e6,
                           "dur": max(0.0, (t_end - t_start) * 1e6),
                           "args": dict(args)})
        for name, track, t, args in instants:
            events.append({"ph": "i", "pid": 1, "tid": tid[track],
                           "name": name, "s": "t",
                           "ts": (t - t0) * 1e6, "args": dict(args)})
        for name, track, t, value in counter_events:
            events.append({"ph": "C", "pid": 1, "tid": tid[track],
                           "name": name, "ts": (t - t0) * 1e6,
                           "args": {"value": value}})
        events.sort(key=lambda e: e.get("ts", -1.0))
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str) -> dict:
        """Write the Chrome-trace JSON to ``path`` and return the dict."""
        trace = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(trace, f)
        return trace


class NullTracer:
    """The default no-op tracer: every record is skipped, ``enabled`` is
    False so per-instruction hot paths pay one attribute check."""

    enabled = False
    detail = "phase"

    class _NullSpan:
        __slots__ = ()

        def set(self, **args) -> "NullTracer._NullSpan":
            return self

        def __enter__(self) -> "NullTracer._NullSpan":
            return self

        def __exit__(self, *exc) -> bool:
            return False

    _SPAN = _NullSpan()

    def span(self, name: str, track: str | None = None, **args):
        return self._SPAN

    def add_span(self, name: str, track: str, t_start: float, t_end: float,
                 overlap_ok: bool = False, **args) -> None:
        pass

    def instant(self, name: str, track: str | None = None, **args) -> None:
        pass

    def count(self, name: str, delta: float = 1.0,
              track: str = "counters") -> None:
        pass

    def counter(self, name: str, value: float,
                track: str = "counters") -> None:
        pass

    def spans(self, prefix: str | None = None,
              track: str | None = None) -> list[SpanRecord]:
        return []

    def counters(self) -> dict[str, float]:
        return {}

    def tracks(self) -> list[str]:
        return []

    def nesting_errors(self, eps: float = 1e-9) -> list[str]:
        return []

    def chrome_trace(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str) -> dict:
        trace = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(trace, f)
        return trace


NULL_TRACER = NullTracer()


def as_tracer(value: Any) -> Tracer | NullTracer:
    """Normalize a user-facing trace knob: ``None``/``False`` -> the no-op
    tracer, ``True`` -> a fresh :class:`Tracer`, a tracer -> itself."""
    if value is None or value is False:
        return NULL_TRACER
    if value is True:
        return Tracer()
    return value
