"""Unified observability: span tracing, counters, and Perfetto export.

See :mod:`repro.obs.tracer` for the recorder and
:mod:`repro.obs.report` for the measured-vs-modeled per-phase join.
``docs/observability.md`` documents the span taxonomy and counter names.
"""

from repro.obs.tracer import (NULL_TRACER, NullTracer, SpanRecord, Tracer,
                              as_tracer)
from repro.obs.report import (PhaseRow, TraceReport, overlap_from_trace,
                              predicted_phase_cycles)

__all__ = [
    "NULL_TRACER", "NullTracer", "SpanRecord", "Tracer", "as_tracer",
    "PhaseRow", "TraceReport", "overlap_from_trace",
    "predicted_phase_cycles",
]
