"""``TraceReport`` — measured-vs-modeled per-phase accounting from a trace.

The calibration loop (ROADMAP: fit ``CycleParams`` against measured
timings) needs one table: for every phase of a compiled program, the wall
time its ``phase/{index}/{kind}`` spans actually measured next to the
cycles the analytic model predicted.  This module joins the two:

* **measured** — the tracer's phase spans (``phase/3/tmu`` named by
  :meth:`~repro.compiler.api.CompiledTMProgram.run_phase`), summed per
  phase across executions;
* **modeled** — per-phase predicted cycles: a TMU phase's scheduled
  (forwarded, or chained when pinned) cycles, a TPU phase's data-movement
  proxy (inputs+outputs through the port — the same proxy
  :func:`repro.serving.server.predict_cycles` totals program-wide).

``overlap()`` reduces the trace's *engine-track* spans (the stream events'
realized busy intervals) to the same both-busy/any-busy ratio
:class:`~repro.serving.stats.ServerStats` measures — the two must agree,
they are the same intervals through two pipelines.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.runtime.streams import intersect_seconds, merge_intervals

__all__ = ["PhaseRow", "TraceReport", "predicted_phase_cycles"]


def _nbytes(graph, name: str) -> int:
    buf = graph.buffers[name]
    n = int(np.dtype(buf.dtype).itemsize)
    for d in buf.shape:
        n *= int(d)
    return n


def predicted_phase_cycles(compiled, *, fuse_chains: bool = False,
                           ) -> dict[int, float]:
    """Cycle-model prediction per phase index of ``compiled``.

    TMU phases report their scheduled cycles (chained when ``fuse_chains``
    pins megakernel execution, so the prediction describes the execution
    shape that runs); TPU phases report the data-movement floor — every
    node's inputs+outputs through the port at ``bandwidth_bytes``/cycle."""
    from repro.core.schedule import CycleParams

    params = compiled.params or CycleParams()
    out: dict[int, float] = {}
    for phase in compiled.partition_report.phases:
        if phase.kind == "tmu":
            sched = phase.schedule
            out[phase.index] = (sched.chained_cycles if fuse_chains
                                else sched.forwarded_cycles)
        else:
            cycles = 0.0
            for i in phase.node_indices:
                node = compiled.graph.nodes[i]
                for name in tuple(node.src_names) + tuple(node.dst_names):
                    if name is not None:
                        cycles += (_nbytes(compiled.graph, name)
                                   / params.bandwidth_bytes)
            out[phase.index] = cycles
    return out


@dataclasses.dataclass(frozen=True)
class PhaseRow:
    """One phase's measured-vs-modeled join."""

    phase: int
    kind: str                # "tmu" | "tpu"
    engine: str
    executions: int          # phase spans observed in the trace
    measured_s: float        # summed span wall time
    mean_s: float
    predicted_cycles: float
    measured_share: float    # of total measured phase time
    predicted_share: float   # of total predicted cycles

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class TraceReport:
    """Measured-vs-modeled per-phase table + trace-derived overlap."""

    rows: list[PhaseRow]
    tracer: object = None     # the source tracer (kept for overlap())

    @staticmethod
    def from_tracer(tracer, compiled, *, fuse_chains: bool = False,
                    ) -> "TraceReport":
        """Join ``tracer``'s phase spans against ``compiled``'s cycle model.

        Phases never observed in the trace still get a row (0 executions),
        so a gap — a phase the workload never exercised — is visible rather
        than silently absent."""
        predicted = predicted_phase_cycles(compiled, fuse_chains=fuse_chains)
        measured: dict[int, list[float]] = {i: [] for i in predicted}
        for span in tracer.spans(prefix="phase/"):
            parts = span.name.split("/")
            try:
                idx = int(parts[1])
            except (IndexError, ValueError):
                continue
            if idx in measured:
                measured[idx].append(span.duration_s)
        total_meas = sum(sum(v) for v in measured.values()) or 1.0
        total_pred = sum(predicted.values()) or 1.0
        rows = []
        for phase in compiled.partition_report.phases:
            walls = measured[phase.index]
            meas = sum(walls)
            rows.append(PhaseRow(
                phase=phase.index, kind=phase.kind, engine=phase.engine,
                executions=len(walls), measured_s=meas,
                mean_s=meas / len(walls) if walls else 0.0,
                predicted_cycles=predicted[phase.index],
                measured_share=meas / total_meas,
                predicted_share=predicted[phase.index] / total_pred))
        return TraceReport(rows=rows, tracer=tracer)

    # --- views ------------------------------------------------------------
    def table(self) -> list[dict]:
        """JSON-safe rows — what the benchmarks embed in ``BENCH_*.json``."""
        return [r.as_dict() for r in self.rows]

    def covered(self) -> bool:
        """True when every phase was executed at least once in the trace."""
        return all(r.executions > 0 for r in self.rows)

    def summary(self) -> str:
        lines = [f"{'phase':>5s} {'kind':>4s} {'runs':>5s} "
                 f"{'measured':>11s} {'meas%':>7s} "
                 f"{'modeled cyc':>12s} {'model%':>7s}"]
        for r in self.rows:
            lines.append(
                f"{r.phase:>5d} {r.kind:>4s} {r.executions:>5d} "
                f"{r.measured_s * 1e3:>9.2f}ms {r.measured_share:>7.1%} "
                f"{r.predicted_cycles:>12.0f} {r.predicted_share:>7.1%}")
        return "\n".join(lines)

    def overlap(self) -> dict:
        """Both-busy/any-busy ratio from the trace's engine tracks — the
        stream events' realized busy intervals, i.e. the same quantity
        :meth:`ServerStats.overlap_ratio` accumulates."""
        return overlap_from_trace(self.tracer)


def overlap_from_trace(tracer, engines: tuple[str, ...] = ("tmu", "tpu"),
                       ) -> dict:
    """Reduce engine-track spans to measured two-engine overlap."""
    lanes = []
    busy = {}
    for engine in engines:
        merged = merge_intervals([(s.t_start, s.t_end)
                                  for s in tracer.spans(track=engine)])
        lanes.append(merged)
        busy[engine] = sum(t1 - t0 for t0, t1 in merged)
    both = intersect_seconds(lanes[0], lanes[1]) if len(lanes) == 2 else 0.0
    any_busy = sum(busy.values()) - both
    return {
        "engine_busy_s": busy,
        "any_busy_s": any_busy,
        "both_busy_s": both,
        "overlap_ratio": both / any_busy if any_busy > 0 else 0.0,
    }
