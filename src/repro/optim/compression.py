"""Gradient compression with error feedback (cross-pod traffic reduction).

At 512+ chips the pod-crossing gradient all-reduce rides slower DCN links.
The standard mitigation is lossy compression with *error feedback*: quantize
each gradient tensor to int8 (per-tensor scale), carry the quantization
residual into the next step.  EF keeps SGD/Adam convergence (Karimireddy et
al. 2019) while cutting cross-pod bytes 4× vs bf16 (8× vs fp32).

In the pjit programming model the all-reduce is implicit, so the lowered
artifact communicates whatever dtype the gradient tensors have at the psum:
``compress_decompress`` rounds the values to their int8 representation (the
bits that would cross the wire) and returns the dequantized fp32, plus the
new error-feedback state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compression_init(grads):
    return jax.tree.map(lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads)


def _quantize_one(g, err):
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127)
    deq = q * scale
    return deq, g - deq


def compress_decompress(grads, ef_state):
    """Returns (dequantized_grads, new_ef_state, bytes_ratio)."""
    out = jax.tree.map(_quantize_one, grads, ef_state)
    deq = jax.tree.map(lambda o: o[0], out, is_leaf=lambda o: isinstance(o, tuple))
    ef = jax.tree.map(lambda o: o[1], out, is_leaf=lambda o: isinstance(o, tuple))
    return deq, ef
