from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,  # noqa: F401
                               cosine_schedule, global_norm_clip)
from repro.optim.compression import (compress_decompress,  # noqa: F401
                                     compression_init)
