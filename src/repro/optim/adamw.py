"""AdamW with fp32 master weights, global-norm clipping, cosine schedule.

Mixed-precision discipline (production TPU default): model params live in
bf16 (what matmuls read), the optimizer keeps fp32 master weights + moments.
Master/moments inherit the parameter shardings, so under FSDP rules the
optimizer state is fully sharded over the data axis (ZeRO-style).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class AdamWState:
    step: jnp.ndarray
    master: Any   # fp32 copies of params
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    f32 = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = jax.tree.map(jnp.zeros_like, f32)
    return AdamWState(step=jnp.zeros((), jnp.int32), master=f32,
                      m=zeros, v=jax.tree.map(jnp.zeros_like, f32))


def global_norm_clip(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(grads, state: AdamWState, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, max_norm=1.0, param_dtype=jnp.bfloat16):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = global_norm_clip(grads, max_norm)
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(g, mu, nu, w):
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu / c1
        nhat = nu / c2
        w = w - lr * (mhat / (jnp.sqrt(nhat) + eps) + weight_decay * w)
        return mu, nu, w

    out = jax.tree.map(upd, grads, state.m, state.v, state.master)
    m = jax.tree.map(lambda o: o[0], out, is_leaf=lambda o: isinstance(o, tuple))
    v = jax.tree.map(lambda o: o[1], out, is_leaf=lambda o: isinstance(o, tuple))
    master = jax.tree.map(lambda o: o[2], out, is_leaf=lambda o: isinstance(o, tuple))
    params = jax.tree.map(lambda w: w.astype(param_dtype), master)
    new_state = AdamWState(step=step, master=master, m=m, v=v)
    return params, new_state, {"grad_norm": gnorm}


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    t = step.astype(jnp.float32)
    warm = peak_lr * t / jnp.maximum(warmup, 1)
    prog = jnp.clip((t - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 *
                     (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(t < warmup, warm, cos)


jax.tree_util.register_pytree_node(
    AdamWState,
    lambda s: ((s.step, s.master, s.m, s.v), None),
    lambda _, c: AdamWState(step=c[0], master=c[1], m=c[2], v=c[3]),
)
