"""Stream-runtime pipeline benchmark — realized TMU/TPU overlap vs blocking.

The async-engine refactor's acceptance measurement: a mixed CNN workload
(two conv-head + TM-tail blocks, the paper's superres / neck shapes) is
served twice over the SAME warm compile-cache entries —

* **blocking** — every request executes its phase chain synchronously on
  one thread (``CompiledTMProgram.run`` without a runtime): the TMU and TPU
  engines strictly alternate, the pre-refactor execution model;
* **pipelined** — the same requests through :class:`TMServer`, whose
  depth-2 pipeline submits each request's phase DAG onto the per-engine
  streams (:mod:`repro.runtime.streams`): request *i+1*'s TM tail runs on
  the TMU stream while request *i*'s conv head occupies the TPU stream.

Emits ``BENCH_pipeline.json`` (best of ``N_RUNS`` paired rounds per path,
realized overlap ratio from event timestamps next to the cycle model's
prediction).

Acceptance gate (CI): pipelined wall must beat blocking by >= 1.15x, and
the measured overlap ratio must be positive — the overlap is *realized*,
not merely modeled.  The gated statistic is BEST wall vs BEST wall over
alternating-order rounds (the ``trace_gate`` discipline): per-round walls
swing tens of percent under machine load and going first measurably
flatters a path, so each path's minimum — its least-noise observation of
the cost floor — is the only estimator tight enough for a fixed-ratio
gate; the round medians are reported as diagnostics.

The speedup gate is parallelism-aware.  Overlap is a *parallel hardware*
effect: with two engines' phases running on two OS threads, a wall-clock
win requires at least two cores to schedule them on.  On a single-core
host the two streams time-slice one CPU — total compute is conserved, a
>1x speedup is physically unreachable, and the only meaningful bound is
that stream dispatch doesn't *collapse* throughput.  So when
``os.cpu_count() < 2`` the gate degrades to a floor
(``GATE_SPEEDUP_SINGLE_CORE``): pipelined must stay within ~25% of
blocking, overlap must still be realized, and outputs must still be
bit-exact.  The applied gate and the detected core count are recorded in
the JSON so CI logs show which regime gated the run.

    PYTHONPATH=src python benchmarks/pipeline_overlap.py
"""

from __future__ import annotations

import json
import os
import statistics
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.serving import ServerConfig, TMServer

GATE_SPEEDUP = 1.15             # >= 2 cores: the overlap win must be real
GATE_SPEEDUP_SINGLE_CORE = 0.75  # 1 core: dispatch-overhead floor only
N_RUNS = 8                 # paired rounds per path (even: alternating
                           # within-round order stays balanced)
N_REQUESTS = 10            # per measured pass (5 per block class)
SUPERRES_SHAPE = (1, 96, 96, 3)
NECK_SHAPE = (1, 96, 96, 3)
C_MID = 256
NECK_C = 288

_ks = jax.random.split(jax.random.PRNGKey(0), 8)


def _dense(k, cin, cout):
    return jax.random.normal(k, (cin, cout), jnp.float32) * cin ** -0.5


# Pointwise (1x1) conv heads: dot_general vmaps without the batching-rule
# reshapes a spatial conv inserts, so each head traces to ONE opaque TPU
# phase and each tail to ONE TM phase — the two-engine ping-pong the paper
# pipelines, without phase fragmentation noise in the measurement.
_SR = (_dense(_ks[0], 3, C_MID), _dense(_ks[1], C_MID, C_MID),
       _dense(_ks[2], C_MID, 32))
_NK = (_dense(_ks[3], 3, NECK_C), _dense(_ks[4], NECK_C, NECK_C),
       _dense(_ks[5], NECK_C, 4))


def superres_block(x):
    """Conv head -> the superres tail (depth-to-space, crop, re-pad)."""
    h = jax.nn.relu(jnp.einsum("bhwc,co->bhwo", x, _SR[0]))
    h = jax.nn.relu(jnp.einsum("bhwc,co->bhwo", h, _SR[1]))
    h = jnp.einsum("bhwc,co->bhwo", h, _SR[2])
    B, H, W, C = h.shape
    s = 2
    c = C // (s * s)
    t = h.reshape(B, H, W, s, s, c)
    t = jnp.transpose(t, (0, 1, 3, 2, 4, 5))
    t = t.reshape(B, H * s, W * s, c)
    t = jax.lax.slice(t, (0, s, s, 0), (B, H * s - s, W * s - s, c))
    return jnp.pad(t, ((0, 0), (1, 1), (1, 1), (0, 0)))


def neck_block(x):
    """Conv head -> the YOLO neck tail (2x upsample + flip + route concat)."""
    h = jax.nn.relu(jnp.einsum("bhwc,co->bhwo", x, _NK[0]))
    h = jax.nn.relu(jnp.einsum("bhwc,co->bhwo", h, _NK[1]))
    h = jnp.einsum("bhwc,co->bhwo", h, _NK[2])
    B, H, W, C = h.shape
    u = jnp.broadcast_to(h[:, :, None, :, None, :], (B, H, 2, W, 2, C))
    u = u.reshape(B, H * 2, W * 2, C)             # nearest 2x upsample
    return jnp.concatenate([u, u], axis=-1)       # TM Route (two bands)


def _requests(rng):
    """Interleaved mixed traffic: (fn, fn_key, args) per request."""
    reqs = []
    for i in range(N_REQUESTS):
        if i % 2 == 0:
            x = jnp.asarray(rng.rand(*SUPERRES_SHAPE).astype(np.float32))
            reqs.append((superres_block, "superres", (x,)))
        else:
            x = jnp.asarray(rng.rand(*NECK_SHAPE).astype(np.float32))
            reqs.append((neck_block, "neck", (x,)))
    return reqs


def _warm_entries(srv, rng):
    """Admit one request per block class (compile + config selection), then
    return the pinned cache entries keyed by fn_key."""
    for fn, fn_key, args in _requests(rng)[:2] * 2:
        srv(fn, *args, fn_key=fn_key)
    entries = {}
    for key in srv.cache.keys():
        entries[key.fn_key] = srv.cache.get(key)
    return entries


def bench_blocking(entries, reqs) -> float:
    """Every request's phase chain, synchronously, on this one thread —
    same compiled entries, same pinned backend/chaining, no streams."""
    t0 = time.perf_counter()
    for _fn, fn_key, args in reqs:
        entry = entries[fn_key]
        stacked = tuple(jnp.stack([a]) for a in args)   # the batch-1 lift
        out, _ = entry.compiled.run(*stacked, backend=entry.backend,
                                    fuse_chains=entry.fuse_chains)
        jax.block_until_ready(out)
    return time.perf_counter() - t0


def bench_pipelined(srv, reqs) -> float:
    """The same requests through the server's stream-dispatched pipeline."""
    t0 = time.perf_counter()
    futs = [srv.submit(fn, *args, fn_key=fn_key)
            for fn, fn_key, args in reqs]
    for f in futs:
        f.result(timeout=600)
    return time.perf_counter() - t0


def main() -> None:
    rng = np.random.RandomState(0)
    cfg = ServerConfig(max_batch=1, batch_timeout_s=0.001,
                       pipeline_depth=2, backend="pallas")
    with TMServer(cfg) as srv:
        entries = _warm_entries(srv, rng)
        # parity first: the pipelined path must be bit-exact vs blocking
        fn, fn_key, args = _requests(rng)[0]
        want = np.asarray(srv(fn, *args, fn_key=fn_key))
        entry = entries[fn_key]
        stacked = tuple(jnp.stack([a]) for a in args)
        got, _ = entry.compiled.run(*stacked, backend=entry.backend,
                                    fuse_chains=entry.fuse_chains)
        exact = bool(np.array_equal(np.asarray(got)[0], want))

        blocking, pipelined = [], []
        for i in range(N_RUNS):                 # paired rounds; within-round
            reqs = _requests(rng)               # order alternates so drift
            passes = [("blocking",              # hits both paths equally
                       lambda: bench_blocking(entries, reqs)),
                      ("pipelined",
                       lambda: bench_pipelined(srv, reqs))]
            if i % 2:
                passes.reverse()
            for tag, run in passes:
                (blocking if tag == "blocking" else pipelined).append(run())
        snap = srv.snapshot_stats()

    blocking_best = min(blocking)
    pipelined_best = min(pipelined)
    speedup = blocking_best / pipelined_best
    blocking_med = statistics.median(blocking)
    pipelined_med = statistics.median(pipelined)
    cpu_count = os.cpu_count() or 1
    gate = GATE_SPEEDUP if cpu_count >= 2 else GATE_SPEEDUP_SINGLE_CORE
    result = {
        "workload": {
            "blocks": ["superres", "neck"],
            "requests_per_run": N_REQUESTS,
            "runs": N_RUNS,
            "superres_shape": SUPERRES_SHAPE,
            "neck_shape": NECK_SHAPE,
            "c_mid": C_MID,
            "neck_c": NECK_C,
            "backend": cfg.backend,
            "pipeline_depth": cfg.pipeline_depth,
        },
        "blocking_wall_s": blocking_best,
        "pipelined_wall_s": pipelined_best,
        "blocking_wall_s_median": blocking_med,
        "pipelined_wall_s_median": pipelined_med,
        "blocking_wall_s_runs": blocking,
        "pipelined_wall_s_runs": pipelined,
        "speedup": speedup,
        "speedup_median": blocking_med / pipelined_med,
        "bit_exact": exact,
        "overlap_ratio_measured": snap["overlap_ratio"],
        "predicted_overlap": snap["predicted_overlap"],
        "engine_busy_s": snap["engine_busy_s"],
        "cpu_count": cpu_count,
        "gate_speedup": gate,
        "gate_regime": "parallel" if cpu_count >= 2 else "single-core",
    }
    with open("BENCH_pipeline.json", "w") as f:
        json.dump(result, f, indent=2)

    print(f"blocking  (best of {N_RUNS}): {blocking_best * 1e3:8.1f} ms "
          f"/ {N_REQUESTS} requests (median {blocking_med * 1e3:.1f} ms)")
    print(f"pipelined (best of {N_RUNS}): {pipelined_best * 1e3:8.1f} ms "
          f"/ {N_REQUESTS} requests (median {pipelined_med * 1e3:.1f} ms)")
    print(f"speedup: {speedup:.2f}x best-vs-best (gate >= {gate}x "
          f"[{result['gate_regime']}, {cpu_count} core(s)]; "
          f"median {blocking_med / pipelined_med:.2f}x)")
    print(f"overlap: {snap['overlap_ratio']:.1%} measured from event "
          f"timestamps / {snap['predicted_overlap']:.1%} predicted")
    print(f"bit-exact vs blocking: {exact}")
    if cpu_count < 2:
        print("note: single-core host — two streams time-slice one CPU, a "
              "wall-clock overlap win is unreachable; gating dispatch "
              "overhead only")

    if not exact:
        raise SystemExit("FAIL: pipelined output diverged from blocking")
    if snap["overlap_ratio"] <= 0.0:
        raise SystemExit("FAIL: no realized engine overlap was measured")
    if speedup < gate:
        raise SystemExit(f"FAIL: pipelined speedup {speedup:.2f}x under the "
                         f"{gate}x gate")
    print("PASS")


if __name__ == "__main__":
    main()
