"""Abstraction-cost table — paper Table V analogue.

We cannot synthesize silicon; the measurable analogue of the paper's
area/power argument is the *configuration cost of reconfigurability*: the
TMU needs only (A, B) register loads per operator (0.019 mm² of datapath),
where fixed-function designs need a datapath per op.  Here we count, per
operator: bytes of the serialized TMInstr (the register-file image), and
verify ALL operators execute on the single shared engine (one datapath).

The paper's silicon numbers are echoed for context: TMU 0.019 mm² / 2.7 mW
@ 40 nm / 300 MHz vs AME 0.291 mm² (norm.) / 4.1 mW; 0.07% of the 26.96 mm²
TPU.
"""

from __future__ import annotations

import json

from repro.core import affine as af
from repro.core.instr import RMEConfig, TMInstr, TMOpcode, TMProgram

SHAPE = (448, 448, 64)


def op_instrs():
    H, W, C = SHAPE
    return {
        "transpose": TMInstr(TMOpcode.COARSE, ("x",), "y",
                             map_=af.transpose_map(SHAPE)),
        "rot90": TMInstr(TMOpcode.COARSE, ("x",), "y", map_=af.rot90_map(SHAPE)),
        "img2col": TMInstr(TMOpcode.COARSE, ("x",), "y",
                           map_=af.img2col_map(SHAPE, 3, 3, 1, 1)),
        "pixelshuffle": TMInstr(TMOpcode.COARSE, ("x",), "y",
                                map_=af.pixel_shuffle_map(SHAPE, 2)),
        "pixelunshuffle": TMInstr(TMOpcode.COARSE, ("x",), "y",
                                  map_=af.pixel_unshuffle_map(SHAPE, 2)),
        "upsample": TMInstr(TMOpcode.COARSE, ("x",), "y",
                            map_=af.upsample_map(SHAPE, 2)),
        "split": TMInstr(TMOpcode.COARSE, ("x",), "y",
                         map_=af.split_map(SHAPE, 2, 0)),
        "route": TMInstr(TMOpcode.COARSE, ("a", "b"), "y",
                         maps=tuple(af.route_maps([SHAPE, SHAPE]))),
        "rearrange": TMInstr(TMOpcode.COARSE, ("x",), "y",
                             map_=af.rearrange_map((448, 448, 3), 1, 16)),
        "bboxcal": TMInstr(TMOpcode.FINE_EVALUATE, ("x",), "y",
                           rme=RMEConfig(scheme="evaluate", threshold=0.5,
                                         capacity=1024, score_index=4)),
        "add": TMInstr(TMOpcode.COARSE, ("a", "b"), "y",
                       map_=af.identity_map(SHAPE), ew=__import__(
                           "repro.core.instr", fromlist=["EwOp"]).EwOp.ADD),
        "rot180(new)": TMInstr(TMOpcode.COARSE, ("x",), "y",
                               map_=af.MixedRadixMap(
                                   out_shape=SHAPE, in_shape=SHAPE, splits=(),
                                   affine=af.AffineMap.make(
                                       [[-1, 0, 0], [0, -1, 0], [0, 0, 1]],
                                       [SHAPE[0] - 1, SHAPE[1] - 1, 0]))),
    }


PAPER_TABLE_V = {
    "TMU (this work)": dict(tech="40nm", freq_mhz=300, area_mm2=0.019,
                            power_mw=2.7, reconfigurable=True),
    "AME [29]": dict(tech="7nm (0.291 norm.)", freq_mhz=2100, area_mm2=0.034,
                     power_mw=4.1, reconfigurable=False),
    "ECNN [30]": dict(tech="40nm", freq_mhz=250, area_mm2=2.26, power_mw=100,
                      reconfigurable=False),
}


def main():
    print("# area_power (Table V analogue): configuration cost of the "
          "unified abstraction")
    print(f"{'operator':16s}{'instr_bytes':>12s}{'datapath':>10s}")
    rows = []
    for name, instr in op_instrs().items():
        nbytes = len(json.dumps(instr.encode()))
        rows.append({"op": name, "instr_bytes": nbytes})
        print(f"{name:16s}{nbytes:>12d}{'shared':>10s}")
    print("\n# paper-reported silicon (for context):")
    for k, v in PAPER_TABLE_V.items():
        print(f"  {k:18s} {v}")
    print("\nAll 12 operators execute on ONE engine (apply_map/RME) — new op "
          "'rot180' required 0 new datapath code (tests/test_executor.py).")
    return rows


if __name__ == "__main__":
    main()
