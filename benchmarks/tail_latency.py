"""Tail-latency benchmark — continuous scheduler vs the FIFO micro-batcher.

The continuous scheduler's acceptance measurement: the SAME seeded open-loop
arrival process (:mod:`repro.sched.loadgen` — Poisson arrivals, mixed sizes,
mixed priority classes, a deadline-carrying slice) is replayed against two
servers that differ only in admission policy —

* **fifo** — the PR-3 power-of-two micro-batcher at its documented operating
  point (5 ms straggler window): a request's group is bound when the batcher
  pops its bucket, and every partial bucket pays the hold;
* **continuous** — :class:`repro.sched.ContinuousScheduler` with a 1 ms
  partial-group hold: groups are re-formed from the live queue each time a
  slot frees (a full group never waits), priorities order dispatch, and
  deadline-risk requests may preempt at phase boundaries.

Both servers are pre-warmed over every (size, bucket-height) shape class, so
the measured window contains no demand compiles; per-request latency is
stamped by future done-callbacks (end-to-end) and by the server's own
admit→first-phase-start series (queue delay).

Emits ``BENCH_tail.json``.  Acceptance gates (CI):

* p99 end-to-end latency under the mixed open-loop load must be >= 1.2x
  BETTER (lower) with the continuous scheduler than with FIFO — best round
  per scheduler over alternating rounds (the ``trace_gate`` discipline);
* scheduler overhead on warm *uniform* traffic (full-group bursts, where
  rolling admission can add nothing) within 5% of FIFO, best wall vs best
  wall;
* served outputs bit-exact against the eager oracle on both paths.

    PYTHONPATH=src python benchmarks/tail_latency.py
"""

from __future__ import annotations

import dataclasses
import json
import statistics
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.sched import LoadSpec, run_load
from repro.serving import ServerConfig, TMServer
from repro.serving.stats import latency_percentiles

GATE_P99_IMPROVEMENT = 1.2      # fifo_p99 / continuous_p99, best-round
GATE_OVERHEAD = 0.05            # uniform warm traffic, best wall vs best wall

MAX_BATCH = 4
SIZES = ((8, 0.6), (16, 0.4))   # square-matrix dims, weighted mix
RATE_UTIL = 0.35                # offered rate vs calibrated serial capacity
TARGET_REQUESTS = 200           # arrivals per measured round
MAX_DURATION_S = 12.0
N_LOAD_ROUNDS = 2               # alternating open-loop rounds per scheduler
N_OVERHEAD_ROUNDS = 8           # alternating uniform-burst rounds
# requests per uniform burst (full groups).  Large on purpose: a burst is
# the overhead gate's unit of observation, and short (~10 ms) bursts left
# the min-of-rounds ratio dominated by single-core scheduling jitter (±10%
# swings between identical runs); ~40 ms bursts average the jitter out and
# the ratio reproduces within ~2%
OVERHEAD_BURST = 128
DEADLINE_FRAC = 0.15            # slice of arrivals carrying a deadline


def workload(x):
    """Manipulation-heavy mixed phases: transpose → einsum (TPU) → pad."""
    y = jnp.tanh(x @ jnp.transpose(x))
    return jnp.pad(y, ((0, 1), (0, 1)))


def _inputs(rng):
    return {dim: jnp.asarray(rng.rand(dim, dim).astype(np.float32))
            for dim, _ in SIZES}


def _make_server(scheduler: str) -> TMServer:
    # identical everywhere but the admission policy; FIFO keeps its
    # documented 5 ms straggler window.  Continuous gets a 1 ms hold: its
    # hold applies to PARTIAL groups only (a full group dispatches the
    # instant it forms), so bursts never wait — the window exists purely so
    # an isolated arrival gives near-simultaneous stragglers one service
    # quantum to coalesce instead of fragmenting into singleton groups
    return TMServer(ServerConfig(
        scheduler=scheduler,
        max_batch=MAX_BATCH,
        batch_timeout_s=0.001 if scheduler == "continuous" else 0.005,
        pipeline_depth=2,
        cache_capacity=64)).start()


def _prewarm(srv: TMServer, inputs) -> None:
    """Compile every (size, bucket-height) class ahead of the measured
    window — the run must contain zero demand compiles."""
    want = 0
    for dim, _ in SIZES:
        h = 1
        while h <= MAX_BATCH:
            srv.prewarm(workload, inputs[dim], fn_key="tail", height=h)
            want += 1
            h *= 2
    deadline = time.monotonic() + 300.0
    while len(srv.cache) < want:
        if time.monotonic() > deadline:
            raise SystemExit(f"prewarm stalled: {len(srv.cache)}/{want} "
                             f"entries after 300 s")
        time.sleep(0.05)


def _calibrate(srv: TMServer, inputs) -> float:
    """Weighted mean warm single-request latency (the serial service time
    the offered rate is scaled against)."""
    per_size = {}
    for dim, _ in SIZES:
        walls = []
        for _ in range(10):
            t0 = time.perf_counter()
            srv(workload, inputs[dim], fn_key="tail")
            walls.append(time.perf_counter() - t0)
        per_size[dim] = statistics.median(walls)
    wtotal = sum(w for _, w in SIZES)
    return sum(per_size[dim] * w for dim, w in SIZES) / wtotal


def _open_loop_round(srv: TMServer, inputs, spec: LoadSpec) -> dict:
    """Replay one seeded arrival schedule; returns e2e + queue-delay
    percentiles for the round."""
    srv.stats.reset_series()
    records = []

    def submit(gr):
        x = inputs[gr.size]
        t0 = time.monotonic()
        fut = srv.submit(workload, x, fn_key="tail",
                         priority=gr.priority, deadline_s=gr.deadline_s)
        rec = {"t0": t0, "fut": fut}
        fut.add_done_callback(
            lambda _f, rec=rec: rec.__setitem__(
                "e2e", time.monotonic() - rec["t0"]))
        records.append(rec)
        return rec

    run_load(submit, spec)
    for rec in records:
        rec["fut"].result(timeout=300)
    e2e = [rec["e2e"] for rec in records]
    snap = srv.snapshot_stats()
    out = {"requests": len(e2e), **latency_percentiles(e2e, "e2e"),
           "e2e_mean_s": sum(e2e) / len(e2e)}
    for k in ("queue_delay_p50_s", "queue_delay_p95_s", "queue_delay_p99_s",
              "mean_batch_size"):
        out[k] = snap[k]
    return out


def _uniform_burst_wall(srv: TMServer, x) -> float:
    t0 = time.perf_counter()
    futs = [srv.submit(workload, x, fn_key="tail")
            for _ in range(OVERHEAD_BURST)]
    for f in futs:
        f.result(timeout=300)
    return time.perf_counter() - t0


def main() -> None:
    rng = np.random.RandomState(0)
    inputs = _inputs(rng)

    servers = {"fifo": _make_server("fifo"),
               "continuous": _make_server("continuous")}
    try:
        for srv in servers.values():
            _prewarm(srv, inputs)

        # parity: both paths must be bit-exact against the eager oracle
        exact = True
        for dim, _ in SIZES:
            want = np.asarray(workload(inputs[dim]))
            for srv in servers.values():
                got = np.asarray(srv(workload, inputs[dim], fn_key="tail"))
                exact = exact and bool(np.array_equal(got, want))

        service_s = _calibrate(servers["continuous"], inputs)
        rate = RATE_UTIL / max(service_s, 1e-4)
        duration = min(TARGET_REQUESTS / rate, MAX_DURATION_S)
        spec = LoadSpec(rate_rps=rate, duration_s=duration, seed=7,
                        sizes=SIZES,
                        priorities=(("interactive", 0.7), ("batch", 0.3)),
                        deadline_s=max(8.0 * service_s, 0.05),
                        deadline_frac=DEADLINE_FRAC)

        # discarded warm round per scheduler: the first open-loop pass pays
        # one-time costs (thread pools spinning up, allocator warm-up) that
        # inflate its tail by orders of magnitude on both paths
        warm_spec = dataclasses.replace(spec, duration_s=min(
            spec.duration_s, 0.1))
        for srv in servers.values():
            _open_loop_round(srv, inputs, warm_spec)

        rounds = {"fifo": [], "continuous": []}
        for i in range(N_LOAD_ROUNDS):          # alternating order: drift
            order = ["fifo", "continuous"]      # hits both schedulers
            if i % 2:                           # equally
                order.reverse()
            for name in order:
                rounds[name].append(
                    _open_loop_round(servers[name], inputs, spec))

        # best round per scheduler: its least-noise observation of the tail
        best = {name: min(rs, key=lambda r: r["e2e_p99_s"])
                for name, rs in rounds.items()}
        p99_improvement = (best["fifo"]["e2e_p99_s"]
                           / best["continuous"]["e2e_p99_s"])

        # uniform warm traffic: full-group bursts, where rolling admission
        # can add nothing — any wall difference IS scheduler overhead
        walls = {"fifo": [], "continuous": []}
        for i in range(N_OVERHEAD_ROUNDS):
            order = ["fifo", "continuous"]
            if i % 2:
                order.reverse()
            for name in order:
                walls[name].append(
                    _uniform_burst_wall(servers[name], inputs[8]))
        overhead = (min(walls["continuous"]) / min(walls["fifo"])) - 1.0

        snaps = {name: srv.snapshot_stats()
                 for name, srv in servers.items()}
    finally:
        for srv in servers.values():
            srv.stop()

    result = {
        "benchmark": "tail_latency",
        "workload": {
            "sizes": SIZES,
            "max_batch": MAX_BATCH,
            "rate_rps": rate,
            "duration_s": duration,
            "deadline_frac": DEADLINE_FRAC,
            "deadline_s": spec.deadline_s,
            "warm_service_s": service_s,
            "load_rounds": N_LOAD_ROUNDS,
            "seed": spec.seed,
        },
        "fifo": {"rounds": rounds["fifo"], "best": best["fifo"]},
        "continuous": {"rounds": rounds["continuous"],
                       "best": best["continuous"],
                       "sched": snaps["continuous"]["sched"]},
        "p99_improvement": p99_improvement,
        "gate_p99_improvement": GATE_P99_IMPROVEMENT,
        "overhead_uniform": overhead,
        "overhead_walls_s": walls,
        "gate_overhead": GATE_OVERHEAD,
        "bit_exact": exact,
        "cache": {name: snaps[name]["cache"] for name in snaps},
    }
    with open("BENCH_tail.json", "w") as f:
        json.dump(result, f, indent=2)

    print("# tail_latency (open-loop Poisson, mixed sizes + priorities)")
    print(f"offered: {rate:.1f} req/s for {duration:.1f} s "
          f"(warm service {service_s * 1e3:.1f} ms, "
          f"{best['fifo']['requests']} arrivals/round)")
    for name in ("fifo", "continuous"):
        b = best[name]
        print(f"{name:>11}: e2e p50 {b['e2e_p50_s'] * 1e3:7.1f} ms | "
              f"p95 {b['e2e_p95_s'] * 1e3:7.1f} ms | "
              f"p99 {b['e2e_p99_s'] * 1e3:7.1f} ms | "
              f"queue-delay p99 {b['queue_delay_p99_s'] * 1e3:7.1f} ms | "
              f"mean batch {b['mean_batch_size']:.2f}")
    print(f"p99 improvement: {p99_improvement:.2f}x "
          f"(gate >= {GATE_P99_IMPROVEMENT}x)")
    print(f"uniform-traffic overhead: {overhead:+.1%} "
          f"(gate <= {GATE_OVERHEAD:.0%})")
    print(f"bit-exact vs eager oracle: {exact}")
    print(f"sched: {snaps['continuous']['sched']}")
    print("wrote BENCH_tail.json")

    if not exact:
        raise SystemExit("FAIL: served outputs diverged from the eager "
                         "oracle")
    if p99_improvement < GATE_P99_IMPROVEMENT:
        raise SystemExit(
            f"FAIL: continuous p99 only {p99_improvement:.2f}x better than "
            f"FIFO (gate {GATE_P99_IMPROVEMENT}x)")
    if overhead > GATE_OVERHEAD:
        raise SystemExit(
            f"FAIL: scheduler overhead {overhead:+.1%} on uniform traffic "
            f"exceeds the {GATE_OVERHEAD:.0%} gate")
    print("PASS")


if __name__ == "__main__":
    main()
