"""Benchmark harness — one module per paper table/figure.

  tm_operators  -> Fig. 8 / Table III (operator-level latency + traffic)
  applications  -> Fig. 10 / Table IV / Fig. 1 (e2e + TM-only latency)
  area_power    -> Table V (abstraction/configuration cost)
  roofline      -> EXPERIMENTS.md §Roofline (from dry-run artifacts)

Prints a final ``name,us_per_call,derived`` CSV summary.
"""

from __future__ import annotations

import argparse


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.25,
                    help="spatial scale of paper Table III shapes")
    ap.add_argument("--skip", nargs="*", default=[],
                    choices=["tm_operators", "applications", "area_power",
                             "roofline", "scaling"])
    args = ap.parse_args(argv)
    csv = ["name,us_per_call,derived"]

    if "tm_operators" not in args.skip:
        from benchmarks import tm_operators
        for r in tm_operators.main(scale=args.scale):
            csv.append(f"tm/{r['op']},{r['standalone_us']:.1f},"
                       f"speedup={r['speedup']:.2f};traffic_red="
                       f"{r['traffic_reduction']:.2f}")
        for r in tm_operators.pipeline_rows(scale=args.scale):
            csv.append(f"pipeline/{r['program']},0,"
                       f"speedup={r['pipeline_speedup']:.2f};e2e_red="
                       f"{r['latency_reduction']:.3f}")
        print()

    if "applications" not in args.skip:
        from benchmarks import applications
        for r in applications.main(scale=args.scale):
            csv.append(f"app/{r['app']},{r['e2e_fused_ms'] * 1e3:.1f},"
                       f"e2e_red={r['e2e_reduction']:.3f};tm_red="
                       f"{r['tm_reduction']:.3f};tm_share="
                       f"{r['tm_share_unfused']:.3f}")
        print()

    if "area_power" not in args.skip:
        from benchmarks import area_power
        for r in area_power.main():
            csv.append(f"instr/{r['op']},0,{r['instr_bytes']}B")
        print()

    if "roofline" not in args.skip:
        from benchmarks import roofline
        for r in roofline.main():
            csv.append(f"roofline/{r['arch']}/{r['shape']},"
                       f"{r['compute_s'] * 1e6:.1f},"
                       f"dom={r['dominant']};util_bound={r['util_bound']:.3f}")
        print()

    if "scaling" not in args.skip:
        from benchmarks import scaling
        for r in scaling.main():
            csv.append(f"scaling/{r['arch']}/{r['shape']},0,"
                       f"compute_eff={r['compute_eff']:.2f};"
                       f"memory_eff={r['memory_eff']:.2f}")
        print()

    print("\n".join(csv))


if __name__ == "__main__":
    main()
