"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
per (arch × shape × mesh): the three roofline terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS ratio, and the bound on achievable compute utilization
(compute_term / max(terms) — what MFU could reach if the dominant
non-compute term were hidden perfectly).
"""

from __future__ import annotations

import glob
import json
import os

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirname="experiments/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(recs, mesh="single"):
    rows = []
    for r in recs:
        if r.get("mesh") != mesh or not r.get("live", False):
            continue
        dom = r["dominant"].replace("_s", "")
        bound = r["step_time_bound_s"]
        util_bound = r["compute_s"] / bound if bound else 0.0
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": dom,
            "useful_flops_ratio": r["useful_flops_ratio"],
            "util_bound": util_bound,
            "bytes_per_device_gb": r["bytes_per_device"] / 1e9,
        })
    rows.sort(key=lambda x: (x["arch"], ORDER.index(x["shape"])))
    return rows


def fmt_markdown(rows, title):
    out = [f"### {title}", "",
           "| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | model/HLO flops | util bound |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['util_bound']:.1%} |")
    return "\n".join(out)


def main(dirname="experiments/dryrun"):
    recs = load(dirname)
    if not recs:
        print(f"# roofline: no dry-run records in {dirname} — run "
              f"`python -m repro.launch.dryrun --all --mesh both` first")
        return []
    for mesh in ("single", "multi"):
        rows = table(recs, mesh)
        print(f"\n# roofline ({mesh}-pod, {len(rows)} live cells)")
        print(f"{'arch':24s}{'shape':>12s}{'compute':>11s}{'memory':>11s}"
              f"{'coll':>11s}{'dominant':>11s}{'m/HLO':>7s}{'util≤':>7s}")
        for r in rows:
            print(f"{r['arch']:24s}{r['shape']:>12s}{r['compute_s']:>11.3e}"
                  f"{r['memory_s']:>11.3e}{r['collective_s']:>11.3e}"
                  f"{r['dominant']:>11s}{r['useful_flops_ratio']:>7.2f}"
                  f"{r['util_bound']:>7.1%}")
    return table(recs, "single")


if __name__ == "__main__":
    main()
