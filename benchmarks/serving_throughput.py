"""Serving-runtime benchmark — the perf trajectory of ``repro.serving``.

Three measurements, emitted as ``BENCH_serving.json`` (archived per commit
by CI, like the compiler trajectory):

* **uncached per-request baseline** — every request pays a fresh
  ``tm_compile`` + execution, the pre-serving workflow;
* **throughput vs. batch size** — a warm :class:`TMServer` at
  ``max_batch`` in {1, 2, 4, 8}: cache-cold admission latency (first pass)
  vs. cache-warm batched throughput (second pass);
* **pipeline overlap** — mixed conv+TM traffic (``espcn``) through the
  two-engine pipeline: measured overlap ratio next to the cycle model's
  prediction.  This pass runs traced, so the report also embeds the
  :class:`~repro.obs.TraceReport` per-phase measured-vs-modeled table
  (``--trace out.json`` additionally exports the Chrome-trace timeline).

Acceptance gate: warm batched serving must clear 2x the uncached
per-request throughput (the compile cache + micro-batching dividend).

    PYTHONPATH=src python benchmarks/serving_throughput.py [--trace out.json]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.compiler import tm_compile
from repro.models import cnn
from repro.obs import Tracer, TraceReport
from repro.serving import ServerConfig, TMServer

SHAPE = (1, 8, 12, 8)          # superres_tail request: x (B,H,W,C), s=2
N_REQUESTS = 16                 # per measured server pass
N_UNCACHED = 8                  # uncached baseline sample size


def _request(rng):
    b, h, w, c = SHAPE
    x = jnp.asarray(rng.rand(b, h, w, c).astype(np.float32))
    skip = jnp.asarray(rng.rand(b, h * 2, w * 2, c // 4).astype(np.float32))
    return x, skip


def bench_uncached(rng) -> dict:
    """Every request: fresh tm_compile + one execution (no cache, batch=1).

    One discarded warmup request first, so one-time jax/XLA jit warmup (which
    the serving path amortizes identically) does not pad the baseline — the
    measured cost is the genuinely per-request work: retrace + passes +
    partition + execution."""
    args = _request(rng)
    jax.block_until_ready(tm_compile(cnn.superres_tail, *args)(*args))
    walls = []
    for _ in range(N_UNCACHED):
        args = _request(rng)
        t0 = time.perf_counter()
        compiled = tm_compile(cnn.superres_tail, *args)
        jax.block_until_ready(compiled(*args))
        walls.append(time.perf_counter() - t0)
    total = sum(walls)
    return {
        "requests": N_UNCACHED,
        "wall_s": total,
        "latency_p50_s": sorted(walls)[len(walls) // 2],
        "requests_per_s": N_UNCACHED / total,
    }


def bench_server(rng, max_batch: int) -> dict:
    """One server: cold pass (admission) then warm measured pass."""
    cfg = ServerConfig(max_batch=max_batch, batch_timeout_s=0.005)
    with TMServer(cfg) as srv:
        def one_pass(n):
            reqs = [_request(rng) for _ in range(n)]
            t0 = time.perf_counter()
            futs = [srv.submit(cnn.superres_tail, *a, fn_key="superres")
                    for a in reqs]
            outs = [f.result(timeout=300) for f in futs]
            wall = time.perf_counter() - t0
            for args, out in zip(reqs, outs):
                assert np.array_equal(np.asarray(out),
                                      np.asarray(cnn.superres_tail(*args)))
            return wall

        cold_wall = one_pass(N_REQUESTS)      # admission compiles here
        warm_wall = one_pass(N_REQUESTS)      # all shape classes cached
        snap = srv.snapshot_stats()
    return {
        "max_batch": max_batch,
        "cold_wall_s": cold_wall,
        "warm_wall_s": warm_wall,
        "warm_requests_per_s": N_REQUESTS / warm_wall,
        "cold_latency_p50_s": snap["cold_latency_p50_s"],
        "warm_latency_p50_s": snap["warm_latency_p50_s"],
        "mean_batch_size": snap["mean_batch_size"],
        "pad_rows": snap["pad_rows"],
        "cache": snap["cache"],
        "exact": True,  # the pass asserts bit-exactness per request
    }


def bench_overlap(rng, tracer: Tracer) -> dict:
    """Mixed conv+TM traffic: the two-engine pipeline's overlap ratio.

    Runs traced so the per-phase wall time of the espcn program can be
    joined against the cycle model's predictions (``trace_report``)."""
    params = cnn.init_espcn(jax.random.PRNGKey(0), s=2)

    def espcn(img):
        return cnn.espcn(params, img)

    cfg = ServerConfig(max_batch=2, batch_timeout_s=0.005, trace=tracer)
    with TMServer(cfg) as srv:
        for _ in range(2):  # warm the cache, then measure steady traffic
            futs = [srv.submit(espcn,
                               jnp.asarray(rng.rand(1, 10, 14, 3)
                                           .astype(np.float32)),
                               fn_key="espcn")
                    for _ in range(8)]
            for f in futs:
                f.result(timeout=300)
        snap = srv.snapshot_stats()
        # join measured per-phase wall time (trace) with the cycle model's
        # per-phase prediction for the one cached espcn program
        entry = srv.cache.get(srv.cache.keys()[0])
        report = TraceReport.from_tracer(tracer, entry.compiled)
    return {
        "overlap_ratio": snap["overlap_ratio"],
        "predicted_overlap": snap["predicted_overlap"],
        "engine_busy_s": snap["engine_busy_s"],
        "pipeline_span_s": snap["pipeline_span_s"],
        "trace_report": {
            "rows": [r.as_dict() for r in report.rows],
            "covered": report.covered(),
            "table": report.table(),
            "summary": report.summary(),
        },
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="export the traced overlap pass as Chrome-trace "
                         "JSON (open at https://ui.perfetto.dev)")
    args = ap.parse_args(argv)

    rng = np.random.RandomState(0)
    uncached = bench_uncached(rng)
    rows = [bench_server(rng, mb) for mb in (1, 2, 4, 8)]
    tracer = Tracer()
    overlap = bench_overlap(rng, tracer)

    best = max(rows, key=lambda r: r["warm_requests_per_s"])
    speedup = best["warm_requests_per_s"] / uncached["requests_per_s"]
    report = {
        "benchmark": "serving_throughput",
        "uncached": uncached,
        "rows": rows,
        "overlap": overlap,
        "best_warm_requests_per_s": best["warm_requests_per_s"],
        "warm_over_uncached_speedup": speedup,
    }

    print("# serving_throughput (TMServer vs per-request tm_compile)")
    print(f"{'max_batch':>10s}{'warm req/s':>12s}{'cold p50':>12s}"
          f"{'warm p50':>12s}{'mean batch':>12s}{'hit rate':>10s}")
    for r in rows:
        print(f"{r['max_batch']:>10d}{r['warm_requests_per_s']:>12.1f}"
              f"{r['cold_latency_p50_s'] * 1e3:>10.1f}ms"
              f"{r['warm_latency_p50_s'] * 1e3:>10.1f}ms"
              f"{r['mean_batch_size']:>12.2f}"
              f"{r['cache']['hit_rate']:>10.2f}")
    print(f"uncached baseline: {uncached['requests_per_s']:.2f} req/s "
          f"(p50 {uncached['latency_p50_s'] * 1e3:.0f} ms)")
    print(f"pipeline overlap: {overlap['overlap_ratio']:.1%} measured / "
          f"{overlap['predicted_overlap']:.1%} predicted (espcn)")
    print(f"warm-batched over uncached: {speedup:.1f}x")
    print("\n# per-phase measured vs modeled (espcn, traced overlap pass)")
    print(overlap["trace_report"]["summary"])

    with open("BENCH_serving.json", "w") as f:
        json.dump(report, f, indent=2)
    print("\nwrote BENCH_serving.json")
    if args.trace:
        trace = tracer.export_chrome_trace(args.trace)
        print(f"trace: {len(trace['traceEvents'])} events -> {args.trace}")
    if speedup < 2.0:
        raise SystemExit(
            f"cache-warm batched serving only {speedup:.2f}x over uncached "
            f"per-request execution (acceptance needs >= 2x)")
    return report


if __name__ == "__main__":
    main()
