"""Application-level benchmark — paper Fig. 10 / Table IV / Fig. 1 analogue.

Measures the four paper applications (ESPCN, EDSR, YOLOv3-Tiny, Attention)
in two execution modes:

  * unfused — every operator runs as its own jit (each TM op round-trips
    "HBM"), the paper's CPU-coupled baseline;
  * fused   — whole network in one jit (TM ops composed into neighbours by
    XLA, the TMU-coupled near-memory mode).

Reports, per application:
  * e2e latency both modes + reduction % (Fig. 10a analogue; paper: 14–35%)
  * TM-op-only latency both modes + reduction % (Fig. 10b; paper: 87–94%)
  * TM share of unfused e2e (Fig. 1; paper: up to 40.62% for EDSR)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core import tm_ops
from repro.models import cnn


def _stage_times(stages, x, reps):
    """Run a list of (name, kind, fn) stages eagerly (own jit each)."""
    ts = {}
    cur = x
    jitted = [(n, k, jax.jit(f)) for n, k, f in stages]
    # warm
    for n, k, f in jitted:
        cur = f(cur)
    outs = cur
    cur = x
    for n, k, f in jitted:
        ts[n] = (k, time_fn(f, cur, reps=reps))
        cur = f(cur)
    return ts, outs


def _fused_time(stages, x, reps):
    def whole(a):
        for _, _, f in stages:
            a = f(a)
        return a
    return time_fn(jax.jit(whole), x, reps=reps)


def _report(name, stages, x, reps=5):
    ts, _ = _stage_times(stages, x, reps)
    t_unfused = sum(t for _, t in ts.values())
    t_tm_unfused = sum(t for k, t in ts.values() if k == "tm")
    t_compute = t_unfused - t_tm_unfused
    t_fused = _fused_time(stages, x, reps)
    t_tm_fused = max(t_fused - t_compute, 0.0)
    return {
        "app": name,
        "e2e_unfused_ms": t_unfused * 1e3,
        "e2e_fused_ms": t_fused * 1e3,
        "e2e_reduction": 1 - t_fused / t_unfused,
        "tm_unfused_ms": t_tm_unfused * 1e3,
        "tm_fused_ms": t_tm_fused * 1e3,
        "tm_reduction": 1 - t_tm_fused / max(t_tm_unfused, 1e-12),
        "tm_share_unfused": t_tm_unfused / t_unfused,
    }


def espcn_stages(key, s=3):
    p = cnn.init_espcn(key, s=s)
    return [
        ("conv1", "compute", lambda x: jnp.tanh(cnn.conv2d(x, p["c1"]))),
        ("conv2", "compute", lambda x: jnp.tanh(cnn.conv2d(x, p["c2"]))),
        ("conv3", "compute", lambda x: cnn.conv2d(x, p["c3"])),
        ("pixelshuffle", "tm", lambda x: tm_ops.pixel_shuffle(x, s)),
    ]


def edsr_stages(key, n_blocks=4, s=2):
    p = cnn.init_edsr(key, n_blocks=n_blocks, s=s)
    stages = [("head", "compute", lambda x: cnn.conv2d(x, p["head"]))]
    for i, blk in enumerate(p["blocks"]):
        stages.append((f"res{i}_convs", "compute",
                       lambda x, b=blk: cnn.conv2d(
                           jax.nn.relu(cnn.conv2d(x, b["c1"])), b["c2"]) * 0.1))
        stages.append((f"res{i}_add", "tm", lambda x: x))  # Add folded below
    # proper residual structure needs two inputs; emulate Add cost with route
    stages.append(("up_conv", "compute", lambda x: cnn.conv2d(x, p["up"])))
    stages.append(("pixelshuffle", "tm", lambda x: tm_ops.pixel_shuffle(x, s)))
    return stages


def edsr_report(key, x, n_blocks=4, s=2, reps=5):
    """EDSR with real residual Adds measured as TM stages."""
    p = cnn.init_edsr(key, n_blocks=n_blocks, s=s)
    conv_head = jax.jit(lambda x: cnn.conv2d(x, p["head"]))
    conv_block = [jax.jit(lambda x, b=b: cnn.conv2d(
        jax.nn.relu(cnn.conv2d(x, b["c1"])), b["c2"])) for b in p["blocks"]]
    add = jax.jit(tm_ops.add)
    conv_up = jax.jit(lambda x: cnn.conv2d(x, p["up"]))
    ps = jax.jit(lambda x: tm_ops.pixel_shuffle(x, s))

    h = conv_head(x)
    t_compute = time_fn(conv_head, x, reps=reps)
    t_tm = 0.0
    for cb in conv_block:
        r = cb(h)
        t_compute += time_fn(cb, h, reps=reps)
        t_tm += time_fn(add, h, r, reps=reps)
        h = add(h, r * 0.1)
    u = conv_up(h)
    t_compute += time_fn(conv_up, h, reps=reps)
    t_tm += time_fn(ps, u, reps=reps)
    t_unfused = t_compute + t_tm
    fused = jax.jit(lambda x: cnn.edsr(p, x))
    t_fused = time_fn(fused, x, reps=reps)
    t_tm_fused = max(t_fused - t_compute, 0.0)
    return {
        "app": "EDSR", "e2e_unfused_ms": t_unfused * 1e3,
        "e2e_fused_ms": t_fused * 1e3,
        "e2e_reduction": 1 - t_fused / t_unfused,
        "tm_unfused_ms": t_tm * 1e3, "tm_fused_ms": t_tm_fused * 1e3,
        "tm_reduction": 1 - t_tm_fused / max(t_tm, 1e-12),
        "tm_share_unfused": t_tm / t_unfused,
    }


def yolo_report(key, x, reps=5):
    p = cnn.init_yolov3_tiny(key, n_classes=80)
    rearr = jax.jit(lambda x: tm_ops.rearrange(x, 1, 16))

    def backbone(z):
        for i, w in enumerate(p["backbone"]):
            z = jax.nn.leaky_relu(cnn.conv2d(z, w), 0.1)
            if i < 5:
                z = jax.lax.reduce_window(z, -jnp.inf, jax.lax.max,
                                          (1, 2, 2, 1), (1, 2, 2, 1), "SAME")
        z = jax.nn.leaky_relu(cnn.conv2d(z, p["conv7"]), 0.1)
        return jax.nn.leaky_relu(cnn.conv2d(z, p["head1_reduce"]), 0.1)

    backbone_j = jax.jit(backbone)
    up = jax.jit(lambda r: tm_ops.upsample(
        jax.nn.leaky_relu(cnn.conv2d(r, p["up_reduce"]), 0.1), 2))
    post = jax.jit(lambda pred: cnn.yolo_postprocess(
        pred, conf_threshold=0.3, capacity=128, max_out=32))

    z0 = rearr(x)
    r = backbone_j(z0)
    pred1 = cnn.conv2d(r, p["head1"])
    t_tm = time_fn(rearr, x, reps=reps)
    t_compute = time_fn(backbone_j, z0, reps=reps)
    t_tm += time_fn(up, r, reps=reps)
    t_tm += time_fn(post, pred1, reps=reps)  # Bboxcal+NMS (fine-grained TM)
    t_unfused = t_compute + t_tm

    def whole(img):
        p1, p2 = cnn.yolov3_tiny(p, img)
        return cnn.yolo_postprocess(p1, conf_threshold=0.3, capacity=128,
                                    max_out=32)

    t_fused = time_fn(jax.jit(whole), x, reps=reps)
    t_tm_fused = max(t_fused - t_compute, 0.0)
    return {
        "app": "YOLOv3-Tiny", "e2e_unfused_ms": t_unfused * 1e3,
        "e2e_fused_ms": t_fused * 1e3,
        "e2e_reduction": 1 - t_fused / t_unfused,
        "tm_unfused_ms": t_tm * 1e3, "tm_fused_ms": t_tm_fused * 1e3,
        "tm_reduction": 1 - t_tm_fused / max(t_tm, 1e-12),
        "tm_share_unfused": t_tm / t_unfused,
    }


def attention_report(key, reps=5):
    """Paper Table IV 'Attention' row (64×768): TM ops are the QKV Split and
    head-layout transposes around the dot products."""
    S, D, H = 64, 768, 12
    hd = D // H
    w = jax.random.normal(key, (D, 3 * D)) * D ** -0.5
    wo = jax.random.normal(key, (D, D)) * D ** -0.5
    x = jax.random.normal(jax.random.fold_in(key, 1), (S, D))

    proj = jax.jit(lambda x: x @ w)
    split_heads = jax.jit(lambda qkv: [
        tm_ops.permute(qkv[:, i * D:(i + 1) * D].reshape(S, H, hd), (1, 0, 2))
        for i in range(3)])
    dots = jax.jit(lambda q, k, v: jax.nn.softmax(
        (q @ k.transpose(0, 2, 1)) / hd ** 0.5) @ v)
    merge = jax.jit(lambda o: tm_ops.permute(o, (1, 0, 2)).reshape(S, D) @ wo)

    qkv = proj(x)
    q, k, v = split_heads(qkv)
    o = dots(q, k, v)
    t_compute = time_fn(proj, x, reps=reps) + time_fn(dots, q, k, v, reps=reps)
    t_tm = time_fn(split_heads, qkv, reps=reps) + time_fn(merge, o, reps=reps)
    t_unfused = t_compute + t_tm

    def whole(x):
        qkv = x @ w
        q, k, v = [tm_ops.permute(qkv[:, i * D:(i + 1) * D].reshape(S, H, hd),
                                  (1, 0, 2)) for i in range(3)]
        o = jax.nn.softmax((q @ k.transpose(0, 2, 1)) / hd ** 0.5) @ v
        return tm_ops.permute(o, (1, 0, 2)).reshape(S, D) @ wo

    t_fused = time_fn(jax.jit(whole), x, reps=reps)
    t_tm_fused = max(t_fused - t_compute, 0.0)
    return {
        "app": "Attention", "e2e_unfused_ms": t_unfused * 1e3,
        "e2e_fused_ms": t_fused * 1e3,
        "e2e_reduction": 1 - t_fused / t_unfused,
        "tm_unfused_ms": t_tm * 1e3, "tm_fused_ms": t_tm_fused * 1e3,
        "tm_reduction": 1 - t_tm_fused / max(t_tm, 1e-12),
        "tm_share_unfused": t_tm / t_unfused,
    }


def main(scale: float = 0.25):
    key = jax.random.PRNGKey(0)
    hw = max(32, int(448 * scale))
    img = jax.random.uniform(key, (1, hw, hw, 3))
    rows = []
    rows.append(_report("ESPCN", espcn_stages(key), img))
    rows.append(edsr_report(key, img))
    rows.append(yolo_report(key, jax.random.uniform(key, (1, 64, 64, 3))))
    rows.append(attention_report(key))
    print("# applications (Fig. 10 / Table IV analogue), img=%dx%d" % (hw, hw))
    hdr = (f"{'app':14s}{'e2e_unfused':>12s}{'e2e_fused':>11s}{'e2e_red':>9s}"
           f"{'tm_red':>8s}{'tm_share':>9s}")
    print(hdr)
    for r in rows:
        print(f"{r['app']:14s}{r['e2e_unfused_ms']:>10.1f}ms"
              f"{r['e2e_fused_ms']:>9.1f}ms{r['e2e_reduction']:>9.1%}"
              f"{r['tm_reduction']:>8.1%}{r['tm_share_unfused']:>9.1%}")
    return rows


if __name__ == "__main__":
    main()
