"""Timing helpers for the benchmark harness."""

from __future__ import annotations

import time

import jax


def time_fn(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def fmt_us(s: float) -> str:
    return f"{s * 1e6:10.1f}"
