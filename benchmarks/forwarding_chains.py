"""Forwarding-chain benchmark — per-instruction vs chain-fused execution.

Runs the three CNN demo blocks (superres_tail, yolo_neck, detect_tail) through
``tm_compile`` and executes the TM phases on the pallas backend twice:

* **unfused** — one kernel launch per instruction, every intermediate
  round-tripping HBM (the per-instruction baseline);
* **chained** — every forwardable chain as ONE segment-streaming megakernel
  (``fuse_chains=True``), intermediates handed off through VMEM scratch.

Emitted as ``BENCH_forwarding.json`` (archived per commit by CI): kernel
launches, modeled HBM traffic (bytes every instruction moves through the
port, minus the round trips chaining elides), wall time, and the cycle
model's chained-vs-pipelined comparison.

Acceptance gates (per block; interpret mode, so the launch/bytes gates carry
the architectural signal and the wall gate guards the realized win):

* chained execution must issue STRICTLY FEWER launches than unfused, and
* chained wall time must beat unfused by >= 1.3x.

    PYTHONPATH=src python benchmarks/forwarding_chains.py
"""

from __future__ import annotations

import json
import math
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.compiler import tm_compile
from repro.models import cnn

MIN_SPEEDUP = 1.3
WARMUP, ITERS = 3, 15


def _blocks(rng):
    def arr(s, scale=1.0):
        return jnp.asarray((rng.rand(*s) * scale).astype(np.float32))

    return [
        ("superres_tail", (lambda a, b: cnn.superres_tail(a, b, s=2)),
         (arr((4, 24, 40, 8)), arr((4, 48, 80, 2)))),
        ("yolo_neck", cnn.yolo_neck,
         (arr((2, 13, 13, 8)), arr((2, 26, 26, 4)))),
        ("detect_tail", (lambda p: cnn.detect_tail_raw(p, 10.0, 16)),
         (arr((8, 13, 13, 30), 100.0),)),
    ]


def _walls(compiled, args) -> tuple[float, float, float]:
    """(unfused s, chained s, speedup) from interleaved paired sampling.

    Unfused and chained calls alternate within one loop so load drift on a
    shared CI runner hits both sides equally; the reported speedup is the
    median of per-pair ratios (robust to scheduler jitter)."""
    def run(fuse_chains):
        t0 = time.perf_counter()
        jax.block_until_ready(jax.tree_util.tree_leaves(
            compiled.run(*args, backend="pallas",
                         fuse_chains=fuse_chains)[0]))
        return time.perf_counter() - t0
    for _ in range(WARMUP):
        run(False), run(True)
    pairs = [(run(False), run(True)) for _ in range(ITERS)]
    unfused = float(np.median([u for u, _ in pairs]))
    chained = float(np.median([c for _, c in pairs]))
    speedup = float(np.median([u / c for u, c in pairs]))
    return unfused, chained, speedup


def _hbm_bytes(compiled, reports=None, itemsize: int = 4) -> int:
    """Modeled HBM traffic of the TM phases: every instruction loads its
    sources and stores its destination.  With ``reports`` (the chained run's
    lowering reports), each REALIZED chain record elides both the store and
    the reload of the intermediates its claimed run streamed — declined
    chains get no credit, so the numbers describe what actually executed."""
    graph = compiled.graph
    total = 0
    tm_phases = compiled.partition_report.tmu_phases
    for pi, ph in enumerate(tm_phases):
        instrs = ph.program.instrs
        for ins in instrs:
            for s in ins.srcs:
                total += math.prod(graph.shape(s)) * itemsize
            total += math.prod(graph.shape(ins.dst)) * itemsize
        if reports is None:
            continue
        dst_index = {ins.dst: k for k, ins in enumerate(instrs)}
        for rec in reports[pi].records:
            if not rec.is_chain:
                continue
            last = dst_index[rec.dst]
            # the claimed run's streamed intermediates: the dsts of its
            # instructions except the final one
            for k in range(last - rec.instrs + 1, last):
                total -= 2 * math.prod(graph.shape(instrs[k].dst)) * itemsize
    return total


def bench_block(name, fn, args) -> dict:
    ref = fn(*args)
    compiled = tm_compile(fn, *args)
    out_u, reps_u = compiled.run(*args, backend="pallas")
    out_c, reps_c = compiled.run(*args, backend="pallas", fuse_chains=True)
    for label, out in (("unfused", out_u), ("chained", out_c)):
        assert np.array_equal(np.asarray(ref, dtype=np.float64),
                              np.asarray(out, dtype=np.float64)), (
            f"{name}:{label} diverged from the raw function")

    launches_u = sum(r.launch_count() for r in reps_u)
    launches_c = sum(r.launch_count() for r in reps_c)
    chains = sum(r.chain_count() for r in reps_c)
    part = compiled.partition_report
    wall_u, wall_c, speedup = _walls(compiled, args)
    row = {
        "block": name,
        "chains": chains,
        "launches_unfused": launches_u,
        "launches_chained": launches_c,
        "hbm_bytes_unfused": _hbm_bytes(compiled),
        "hbm_bytes_chained": _hbm_bytes(compiled, reports=reps_c),
        "wall_unfused_s": wall_u,
        "wall_chained_s": wall_c,
        "speedup": speedup,
        "model_pipelined_cycles": part.pipelined_cycles,
        "model_chained_cycles": part.chained_cycles,
        "model_launches_unfused": part.launches(chained=False),
        "model_launches_chained": part.launches(chained=True),
        "chain_reports": [r for ph in part.tmu_phases
                          for r in (ph.schedule.chain_reports or [])],
    }
    print(f"  {name}: launches {launches_u}->{launches_c} "
          f"({chains} chain(s)), hbm {row['hbm_bytes_unfused']}"
          f"->{row['hbm_bytes_chained']} B, "
          f"wall {wall_u * 1e3:.2f}->{wall_c * 1e3:.2f} ms "
          f"({row['speedup']:.2f}x)")
    return row


def main() -> int:
    rng = np.random.RandomState(0)
    print("forwarding-chain benchmark (pallas, interpret mode)")
    rows = [bench_block(name, fn, args) for name, fn, args in _blocks(rng)]
    report = {"blocks": rows, "min_speedup_gate": MIN_SPEEDUP}
    with open("BENCH_forwarding.json", "w") as f:
        json.dump(report, f, indent=2)
    print("wrote BENCH_forwarding.json")

    failures = []
    for row in rows:
        if row["launches_chained"] >= row["launches_unfused"]:
            failures.append(f"{row['block']}: launches not strictly fewer "
                            f"({row['launches_unfused']} -> "
                            f"{row['launches_chained']})")
        if row["hbm_bytes_chained"] >= row["hbm_bytes_unfused"]:
            failures.append(f"{row['block']}: no HBM traffic elided")
        if row["speedup"] < MIN_SPEEDUP:
            failures.append(f"{row['block']}: speedup {row['speedup']:.2f}x "
                            f"< {MIN_SPEEDUP}x gate")
    if failures:
        print("GATE FAILED:")
        for f_ in failures:
            print(" -", f_)
        return 1
    print(f"gates passed: strictly fewer launches + >= {MIN_SPEEDUP}x "
          f"wall-time on all blocks")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
