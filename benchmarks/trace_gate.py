"""Tracing gate — observability must be cheap, complete, and honest.

One serving workload (``espcn``: conv compute feeding a TM tail, so both
engines run) is measured twice through :class:`TMServer` — untraced and
traced — and the traced run's timeline is checked against three gates:

* **completeness** — every phase of the compiled program has >= 1
  ``phase/{index}/...`` span in the trace (nothing executes unobserved);
* **overhead** — traced warm throughput within ``MAX_OVERHEAD`` (5%) of
  untraced; both servers stay warm, each of the ``N_PASSES`` measured
  rounds runs one pass per mode, and the within-round order ALTERNATES
  each round (going first measurably flatters a pass).  The gated
  statistic is BEST wall vs BEST wall: per-pass walls swing tens of
  percent under machine load, so the minimum — each mode's least-noise
  observation of its cost floor — is the only estimator tight enough for
  a 5% gate (the per-round ratio median is reported as a diagnostic);
* **agreement** — the per-engine-track both-busy overlap recomputed from
  the exported spans (:func:`repro.obs.overlap_from_trace`) matches
  ``ServerStats.overlap_ratio()`` within ``MAX_OVERLAP_DELTA`` (0.02) —
  the trace and the stats must describe the same execution.

Artifacts: ``BENCH_trace.json`` (gate numbers + the per-phase
measured-vs-modeled table) and ``serving.trace.json`` (the Chrome-trace
timeline; open at https://ui.perfetto.dev).

    PYTHONPATH=src python benchmarks/trace_gate.py
"""

from __future__ import annotations

import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import cnn
from repro.obs import Tracer, TraceReport, overlap_from_trace
from repro.serving import ServerConfig, TMServer

SHAPE = (1, 40, 48, 3)          # request image: large enough that per-phase
                                # work dwarfs the fixed per-record trace cost
N_REQUESTS = 16                 # per warm pass
N_PASSES = 20                   # paired rounds (even: the alternating
                                # order stays balanced); passes are ~0.1s,
                                # so many rounds cost little and tighten
                                # the per-mode best-wall estimate
MAX_OVERHEAD = 0.05             # traced warm throughput within 5% of untraced
MAX_OVERLAP_DELTA = 0.02        # trace-derived vs stats overlap agreement
TRACE_PATH = "serving.trace.json"


def main() -> dict:
    params = cnn.init_espcn(jax.random.PRNGKey(0), s=2)

    def espcn(img):
        return cnn.espcn(params, img)

    rng = np.random.RandomState(0)
    # one request stream, shared by every pass of BOTH servers — the modes
    # must differ only in tracing, never in data
    imgs = [jnp.asarray(rng.rand(*SHAPE).astype(np.float32))
            for _ in range(N_REQUESTS)]

    def one_pass(srv):
        t0 = time.perf_counter()
        futs = [srv.submit(espcn, img, fn_key="espcn") for img in imgs]
        for f in futs:
            f.result(timeout=300)
        return time.perf_counter() - t0

    tracer = Tracer()
    results = {}
    with TMServer(ServerConfig(max_batch=2,
                               batch_timeout_s=0.005)) as srv_un, \
         TMServer(ServerConfig(max_batch=2, batch_timeout_s=0.005,
                               trace=tracer)) as srv_tr:
        one_pass(srv_un)                        # cold: compiles here
        one_pass(srv_tr)
        walls_un, walls_tr = [], []
        for i in range(N_PASSES):               # interleave measured passes,
            order = [(srv_un, walls_un), (srv_tr, walls_tr)]
            if i % 2:                           # alternating who goes first
                order.reverse()
            for srv, walls in order:
                walls.append(one_pass(srv))
        for key, srv, walls in (("untraced", srv_un, walls_un),
                                ("traced", srv_tr, walls_tr)):
            best = min(walls)
            results[key] = {
                "warm_walls_s": walls,
                "best_wall_s": best,
                "best_requests_per_s": N_REQUESTS / best,
                "stats": srv.snapshot_stats(),
            }
        compiled = srv_tr.cache.get(srv_tr.cache.keys()[0]).compiled
    untraced, traced = results["untraced"], results["traced"]

    # --- completeness: >= 1 span per phase of the compiled program --------
    n_phases = len(compiled.partition_report.phases)
    spans_per_phase = {
        p.index: len(tracer.spans(prefix=f"phase/{p.index}/"))
        for p in compiled.partition_report.phases}
    unobserved = sorted(i for i, n in spans_per_phase.items() if n == 0)

    # --- overhead: best traced wall vs best untraced wall -----------------
    overhead = traced["best_wall_s"] / untraced["best_wall_s"] - 1.0
    ratios = sorted(t / u for t, u in zip(traced["warm_walls_s"],
                                          untraced["warm_walls_s"]))
    mid = len(ratios) // 2
    median_ratio = (ratios[mid] if len(ratios) % 2
                    else 0.5 * (ratios[mid - 1] + ratios[mid]))

    # --- agreement: overlap from the trace vs from ServerStats ------------
    stats_overlap = traced["stats"]["overlap_ratio"]
    trace_overlap = overlap_from_trace(tracer)
    overlap_delta = abs(trace_overlap["overlap_ratio"] - stats_overlap)

    # --- integrity + artifacts --------------------------------------------
    nesting = tracer.nesting_errors()
    report_tbl = TraceReport.from_tracer(tracer, compiled)
    trace = tracer.export_chrome_trace(TRACE_PATH)

    report = {
        "benchmark": "trace_gate",
        "untraced": {k: v for k, v in untraced.items() if k != "stats"},
        "traced": {k: v for k, v in traced.items() if k != "stats"},
        "round_ratios": ratios,
        "median_round_ratio": median_ratio,
        "overhead": overhead,
        "max_overhead": MAX_OVERHEAD,
        "phases": n_phases,
        "spans_per_phase": spans_per_phase,
        "unobserved_phases": unobserved,
        "overlap_stats": stats_overlap,
        "overlap_trace": trace_overlap["overlap_ratio"],
        "overlap_delta": overlap_delta,
        "max_overlap_delta": MAX_OVERLAP_DELTA,
        "nesting_errors": nesting,
        "trace_events": len(trace["traceEvents"]),
        "trace_report": {
            "rows": [r.as_dict() for r in report_tbl.rows],
            "covered": report_tbl.covered(),
            "table": report_tbl.table(),
        },
    }

    print("# trace_gate (espcn through TMServer, traced vs untraced)")
    print(f"untraced warm: {untraced['best_requests_per_s']:.1f} req/s | "
          f"traced warm: {traced['best_requests_per_s']:.1f} req/s "
          f"(best-wall overhead {overhead:+.1%}, gate {MAX_OVERHEAD:.0%}; "
          f"median round ratio {median_ratio:.3f})")
    print(f"phase spans: {spans_per_phase} over {n_phases} phases")
    print(f"overlap: {stats_overlap:.3f} stats vs "
          f"{trace_overlap['overlap_ratio']:.3f} trace "
          f"(delta {overlap_delta:.4f}, gate {MAX_OVERLAP_DELTA})")
    print(f"trace: {len(trace['traceEvents'])} events -> {TRACE_PATH}")
    print("\n" + report_tbl.summary())

    with open("BENCH_trace.json", "w") as f:
        json.dump(report, f, indent=2)
    print("\nwrote BENCH_trace.json")

    if unobserved:
        raise SystemExit(f"phases executed without a span: {unobserved}")
    if nesting:
        raise SystemExit(f"trace integrity violated: {nesting}")
    if overhead > MAX_OVERHEAD:
        raise SystemExit(
            f"tracing overhead {overhead:.1%} exceeds the "
            f"{MAX_OVERHEAD:.0%} gate "
            f"({traced['best_requests_per_s']:.1f} traced vs "
            f"{untraced['best_requests_per_s']:.1f} untraced req/s)")
    if overlap_delta > MAX_OVERLAP_DELTA:
        raise SystemExit(
            f"trace-derived overlap {trace_overlap['overlap_ratio']:.3f} "
            f"disagrees with ServerStats {stats_overlap:.3f} "
            f"(delta {overlap_delta:.4f} > {MAX_OVERLAP_DELTA})")
    return report


if __name__ == "__main__":
    main()
