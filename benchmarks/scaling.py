"""Multi-pod scaling efficiency from the dry-run artifacts.

Weak-scaling check for the 2-pod mesh: with the global batch fixed, doubling
chips should halve per-device compute/memory terms (efficiency ≈ 1.0); the
collective term gains the cross-pod gradient reduce.  Reads the same JSONs
as benchmarks/roofline.py.
"""

from __future__ import annotations

import glob
import json
import os


def load(dirname="experiments/dryrun"):
    recs = {}
    for p in glob.glob(os.path.join(dirname, "*.json")):
        with open(p) as f:
            d = json.load(f)
        if d.get("live"):
            recs[(d["arch"], d["shape"], d["mesh"])] = d
    return recs


def main(dirname="experiments/dryrun"):
    recs = load(dirname)
    rows = []
    for (arch, shape, mesh), r in sorted(recs.items()):
        if mesh != "single":
            continue
        m = recs.get((arch, shape, "multi"))
        if not m:
            continue
        ceff = r["compute_s"] / (2 * m["compute_s"]) if m["compute_s"] else 0
        meff = r["memory_s"] / (2 * m["memory_s"]) if m["memory_s"] else 0
        coll_ratio = (m["collective_s"] / r["collective_s"]
                      if r["collective_s"] else float("inf"))
        rows.append({"arch": arch, "shape": shape,
                     "compute_eff": ceff, "memory_eff": meff,
                     "collective_x": coll_ratio})
    if not rows:
        print("# scaling: no dry-run records; run the sweep first")
        return rows
    print("# multi-pod weak scaling (512 vs 256 chips, fixed global work)")
    print(f"{'arch':24s}{'shape':>12s}{'compute_eff':>12s}{'memory_eff':>11s}"
          f"{'coll_x':>8s}")
    for r in rows:
        print(f"{r['arch']:24s}{r['shape']:>12s}{r['compute_eff']:>12.2f}"
              f"{r['memory_eff']:>11.2f}{r['collective_x']:>8.2f}")
    return rows


if __name__ == "__main__":
    main()
