"""Compiler end-to-end benchmark — the perf trajectory of tm_compile.

Compiles the demo programs (models.cnn.superres_tail / espcn / yolo_neck /
detect_tail) and records, per program:

  * trace stats (TM instrs, TPU nodes, matched primitives)
  * pass stats (map compositions, epilogue sinks, copies elided, RME
    legalizations)
  * the scheduled cycle model: unpipelined vs double-buffered vs
    partitioned+forwarded, and the end-to-end latency reduction
  * scratch allocation (allocated vs naive bytes)
  * wall time of one pallas-backend execution (interpret mode — a smoke
    number, not a TPU measurement)

Emits ``BENCH_compiler_e2e.json`` in the working directory so CI archives
one point of the trajectory per commit.

    PYTHONPATH=src python benchmarks/compiler_e2e.py
"""

from __future__ import annotations

import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.compiler import tm_compile
from repro.models import cnn


def _demos(rng):
    x = jnp.asarray(rng.rand(2, 32, 32, 32).astype(np.float32))
    skip = jnp.asarray(rng.rand(2, 64, 64, 8).astype(np.float32))
    yield "superres_tail", cnn.superres_tail, (x, skip)

    p = cnn.init_espcn(jax.random.PRNGKey(0), s=2)
    img = jnp.asarray(rng.rand(2, 24, 24, 3).astype(np.float32))
    yield "espcn", (lambda a: cnn.espcn(p, a)), (img,)

    u = jnp.asarray(rng.rand(2, 16, 16, 32).astype(np.float32))
    sk = jnp.asarray(rng.rand(2, 32, 32, 16).astype(np.float32))
    yield "yolo_neck", cnn.yolo_neck, (u, sk)

    pred = jnp.asarray(rng.rand(4, 1024, 85).astype(np.float32) * 100)
    yield "detect_tail", (lambda q: cnn.detect_tail(q, 50.0, 128)), (pred,)


def run() -> list[dict]:
    rng = np.random.RandomState(0)
    rows = []
    for name, fn, args in _demos(rng):
        compiled = tm_compile(fn, *args)
        ref = fn(*args)
        t0 = time.perf_counter()
        got = compiled(*args, backend="pallas")
        wall = time.perf_counter() - t0
        exact = bool(np.array_equal(
            np.asarray(ref, dtype=np.float64),
            np.asarray(got, dtype=np.float64)))
        pr = compiled.partition_report
        rows.append({
            "program": name,
            "tm_instrs": sum(len(p.instrs) for p in compiled.tm_programs),
            "tpu_nodes": len(compiled.graph.tpu_nodes()),
            "matched_prims": sorted(compiled.matched_prims),
            "compositions": compiled.pass_report.compositions,
            "epilogues_sunk": compiled.pass_report.epilogues_sunk,
            "copies_elided": compiled.pass_report.copies_elided,
            "rme_legalized": compiled.pass_report.rme_legalized,
            "unpipelined_cycles": pr.unpipelined_cycles,
            "double_buffered_cycles": pr.pipelined_cycles,
            "forwarded_cycles": pr.forwarded_cycles,
            "forwarding_edges": pr.forwarding_edges,
            "latency_reduction": pr.latency_reduction,
            "scratch_bytes": compiled.scratch_plan.total_bytes,
            "scratch_naive_bytes": compiled.scratch_plan.naive_bytes,
            "pallas_exact": exact,
            "pallas_wall_s": wall,
        })
    return rows


def main() -> list[dict]:
    rows = run()
    print("# compiler_e2e (tm_compile: unpipelined vs partitioned+forwarded)")
    print(f"{'program':16s}{'tm':>4s}{'tpu':>5s}{'fuse':>6s}{'sink':>6s}"
          f"{'unpiped':>12s}{'fwded':>12s}{'e2e_red':>9s}{'exact':>7s}")
    for r in rows:
        print(f"{r['program']:16s}{r['tm_instrs']:>4d}{r['tpu_nodes']:>5d}"
              f"{r['compositions']:>6d}{r['epilogues_sunk']:>6d}"
              f"{r['unpipelined_cycles']:>12.0f}{r['forwarded_cycles']:>12.0f}"
              f"{r['latency_reduction']:>9.2%}{str(r['pallas_exact']):>7s}")
    with open("BENCH_compiler_e2e.json", "w") as f:
        json.dump({"benchmark": "compiler_e2e", "rows": rows}, f, indent=2)
    print("\nwrote BENCH_compiler_e2e.json")
    if not all(r["pallas_exact"] for r in rows):
        raise SystemExit("compiled pallas outputs diverged from reference")
    return rows


if __name__ == "__main__":
    main()
