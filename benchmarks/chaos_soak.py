"""Chaos soak — fault-injected serving must stay live, honest, and cheap.

A seeded open-loop Poisson load (:mod:`repro.sched.loadgen`) is replayed
against a fault-tolerant ``TMServer`` while a seeded
:func:`repro.ft.poisson_plan` injects faults at all four sites — stream
tasks (fail/hang/slow), phase execution, kernel lowering, and compiles — at
~5% per occurrence, and ~2% of arrivals are *victims*: requests whose fn is
deterministically poisoned (raises at trace time) and must keep exactly that
error.  The soak runs on the pallas backend so the lowering site is live and
injected kernel failures exercise the quarantine/degradation ladder.

Gates (CI):

* **no deadlock** — after the arrival window, ``drain`` completes within its
  timeout despite hangs (watchdog-poisoned), failed groups (bisect-retried),
  and quarantined kernels;
* **zero non-victim failures** — every innocent request resolves, and its
  output is **bit-exact** against the eager oracle ``workload(x)``;
* **victims keep their own error** — each poisoned request raises the
  poison ``ValueError`` (never an ``InjectedFault`` or ``PhaseTimeoutError``
  borrowed from an innocent group-mate);
* **coverage** — the injector actually fired at every site;
* **overhead** — warm NON-faulted throughput with the full robustness stack
  armed (isolation + watchdog, hooks installed but never firing) stays
  within 5% of the bare server, best wall over alternating-order rounds.

Emits ``BENCH_chaos.json``.

    PYTHONPATH=src python benchmarks/chaos_soak.py
"""

from __future__ import annotations

import json
import time

import numpy as np
import jax.numpy as jnp

from repro.ft import FaultInjector, FaultPlan, FaultSpec, poisson_plan
from repro.sched import LoadSpec, generate
from repro.serving import ServerConfig, TMServer

FAULT_RATE = 0.05               # per-occurrence fire probability, each site
VICTIM_FRAC = 0.02              # arrivals whose fn is deterministically bad
TARGET_REQUESTS = 150           # arrivals in the chaos window
RATE_RPS = 60.0                 # offered arrival rate
MAX_BATCH = 4
SIZES = ((8, 0.7), (12, 0.3))   # square-matrix dims, weighted mix
SEED = 2024
DRAIN_TIMEOUT_S = 180.0         # the no-deadlock gate
GATE_OVERHEAD = 0.05            # armed-but-quiet vs bare, best wall
OVERHEAD_BURST = 96
N_OVERHEAD_ROUNDS = 6


def workload(x):
    """Manipulation-heavy mixed phases: transpose (TMU) → matmul+tanh
    (TPU) → pad (TMU) — every chaos site has something to hit."""
    y = jnp.tanh(x @ jnp.transpose(x))
    return jnp.pad(y, ((0, 1), (0, 1)))


def poisoned(x):
    raise ValueError("chaos victim")


def _inputs(rng):
    return {dim: jnp.asarray(rng.rand(dim, dim).astype(np.float32))
            for dim, _ in SIZES}


def _prewarm(srv: TMServer, inputs) -> None:
    """Every (size, height) class compiles BEFORE the injector installs:
    the chaos window then contains no innocent demand compiles, so the
    compile site only sees victim traffic (and bisect re-executions hit
    warm singleton classes instead of paying injected compile faults)."""
    want = 0
    for dim, _ in SIZES:
        h = 1
        while h <= MAX_BATCH:
            srv.prewarm(workload, inputs[dim], fn_key="chaos", height=h)
            want += 1
            h *= 2
    deadline = time.monotonic() + 300.0
    while len(srv.cache) < want:
        if time.monotonic() > deadline:
            raise SystemExit(f"prewarm stalled: {len(srv.cache)}/{want}")
        time.sleep(0.05)


def _ft_config(armed: bool) -> ServerConfig:
    # pallas backend: the lowering site only exists on the kernel path.
    # retry_attempts=4 keeps repeated p=0.05 faults on one singleton's
    # re-executions from ever exhausting the budget (p^4 ~ 1e-5).
    return ServerConfig(
        backend="pallas", max_batch=MAX_BATCH, batch_timeout_s=0.002,
        cache_capacity=64,
        retry_attempts=4 if armed else 0,
        phase_timeout_factor=20.0 if armed else 0.0,
        phase_timeout_floor_s=0.25)


def _chaos_window(srv: TMServer, inputs) -> dict:
    spec = LoadSpec(rate_rps=RATE_RPS,
                    duration_s=TARGET_REQUESTS / RATE_RPS, seed=SEED,
                    sizes=SIZES)
    schedule = generate(spec)
    vic_rng = np.random.RandomState(SEED + 2)
    is_victim = vic_rng.rand(len(schedule)) < VICTIM_FRAC
    if not is_victim.any():          # the soak must exercise the victim path
        is_victim[len(is_victim) // 2] = True

    base = poisson_plan(SEED, FAULT_RATE, hang_delay_s=1.0,
                        slow_delay_s=0.02)
    # prewarm keeps innocent demand compiles out of the window, so compile
    # coverage comes from deliberately-cold shape classes (fresh fn_keys
    # sprinkled below) whose first two compiles fail deterministically —
    # count-limited, so isolation's recompile always eventually lands
    plan = FaultPlan(seed=SEED, specs=base.specs + (
        FaultSpec(site="compile", match="chaos-cold", mode="fail", count=2),))
    records = []
    t_start = time.monotonic()
    with FaultInjector(plan) as inj:
        t0 = time.monotonic()
        for i, (gr, victim) in enumerate(zip(schedule, is_victim)):
            delay = t0 + gr.t_arrival - time.monotonic()
            if delay > 0:
                time.sleep(delay)
            if victim:
                fut = srv.submit(poisoned, inputs[gr.size],
                                 fn_key="chaos-victim")
            else:
                key = f"chaos-cold-{i}" if i % 25 == 12 else "chaos"
                fut = srv.submit(workload, inputs[gr.size], fn_key=key)
            records.append((gr, victim, fut))
        # the no-deadlock gate: every future resolves and the server drains
        srv.drain(timeout=DRAIN_TIMEOUT_S)
        inj_snap = inj.snapshot()
    wall_s = time.monotonic() - t_start

    innocents = victims = 0
    innocent_failures = []
    victim_wrong_error = []
    bit_exact = True
    for gr, victim, fut in records:
        if victim:
            victims += 1
            try:
                fut.result(timeout=1.0)
                victim_wrong_error.append("resolved without error")
            except ValueError as e:
                if "chaos victim" not in str(e):
                    victim_wrong_error.append(repr(e))
            except BaseException as e:  # noqa: BLE001 — borrowed error
                victim_wrong_error.append(repr(e))
            continue
        innocents += 1
        try:
            got = fut.result(timeout=1.0)
        except BaseException as e:  # noqa: BLE001 — the gate counts these
            innocent_failures.append(repr(e))
            continue
        want = workload(inputs[gr.size])
        if not np.array_equal(np.asarray(got), np.asarray(want)):
            bit_exact = False

    return {
        "arrivals": len(records),
        "innocents": innocents,
        "victims": victims,
        "wall_s": wall_s,
        "injected": inj_snap,
        "innocent_failures": innocent_failures,
        "victim_wrong_error": victim_wrong_error,
        "bit_exact_survivors": bit_exact,
        "stats": srv.snapshot_stats(),
        "watchdog": srv.watchdog.snapshot() if srv.watchdog else None,
    }


def _burst_wall(srv: TMServer, x) -> float:
    t0 = time.perf_counter()
    futs = [srv.submit(workload, x, fn_key="chaos")
            for _ in range(OVERHEAD_BURST)]
    for f in futs:
        f.result(timeout=300)
    return time.perf_counter() - t0


def _overhead(inputs) -> dict:
    """Warm non-faulted throughput: full robustness stack armed (watchdog
    polling, isolation pool idle, hooks installed but never matching) vs
    the bare server.  Alternating order, best wall vs best wall."""
    servers = {"bare": TMServer(_ft_config(armed=False)).start(),
               "armed": TMServer(_ft_config(armed=True)).start()}
    quiet = FaultPlan(specs=(), seed=0)   # hooks installed, nothing fires
    try:
        for srv in servers.values():
            _prewarm(srv, inputs)
            _burst_wall(srv, inputs[SIZES[0][0]])   # discard first pass
        walls = {"bare": [], "armed": []}
        with FaultInjector(quiet):
            for i in range(N_OVERHEAD_ROUNDS):
                order = ["bare", "armed"]
                if i % 2:
                    order.reverse()
                for name in order:
                    walls[name].append(
                        _burst_wall(servers[name], inputs[SIZES[0][0]]))
    finally:
        for srv in servers.values():
            srv.stop()
    ratio = min(walls["armed"]) / min(walls["bare"]) - 1.0
    return {"walls_s": walls, "overhead": ratio}


def main() -> None:
    rng = np.random.RandomState(0)
    inputs = _inputs(rng)

    srv = TMServer(_ft_config(armed=True)).start()
    try:
        _prewarm(srv, inputs)
        chaos = _chaos_window(srv, inputs)
    finally:
        srv.stop()

    ovh = _overhead(inputs)

    per_site = chaos["injected"]["per_site"]
    uncovered = [s for s in ("stream", "phase", "lowering", "compile")
                 if per_site.get(s, 0) == 0]
    st = chaos["stats"]
    result = {
        "benchmark": "chaos_soak",
        "config": {"fault_rate": FAULT_RATE, "victim_frac": VICTIM_FRAC,
                   "rate_rps": RATE_RPS, "seed": SEED,
                   "max_batch": MAX_BATCH, "sizes": SIZES,
                   "drain_timeout_s": DRAIN_TIMEOUT_S},
        "chaos": chaos,
        "overhead": ovh,
        "gate_overhead": GATE_OVERHEAD,
        "uncovered_sites": uncovered,
    }
    with open("BENCH_chaos.json", "w") as f:
        json.dump(result, f, indent=2, default=str)

    print("# chaos_soak (seeded Poisson load + poisson_plan faults, pallas)")
    print(f"arrivals: {chaos['arrivals']} ({chaos['victims']} victims) "
          f"in {chaos['wall_s']:.1f} s; injected "
          f"{chaos['injected']['fired']} faults {per_site}")
    print(f"recovery: {st['group_faults']} group faults, "
          f"{st['isolation_retries']} isolation retries, "
          f"{st['rescued_requests']} rescued, "
          f"{st['victim_requests']} victims, "
          f"{st['phase_timeouts']} watchdog timeouts, "
          f"{st['degraded_phases']} degraded phases")
    print(f"innocent failures: {len(chaos['innocent_failures'])} | "
          f"victim wrong-error: {len(chaos['victim_wrong_error'])} | "
          f"bit-exact survivors: {chaos['bit_exact_survivors']}")
    print(f"armed-vs-bare warm overhead: {ovh['overhead']:+.1%} "
          f"(gate <= {GATE_OVERHEAD:.0%})")
    print("wrote BENCH_chaos.json")

    if chaos["innocent_failures"]:
        raise SystemExit(f"non-victim requests failed under chaos: "
                         f"{chaos['innocent_failures'][:5]}")
    if not chaos["bit_exact_survivors"]:
        raise SystemExit("surviving outputs are not bit-exact vs the oracle")
    if chaos["victim_wrong_error"]:
        raise SystemExit(f"victims did not keep their poison error: "
                         f"{chaos['victim_wrong_error'][:5]}")
    if uncovered:
        raise SystemExit(f"injector never fired at: {uncovered}")
    if ovh["overhead"] > GATE_OVERHEAD:
        raise SystemExit(f"robustness-stack overhead {ovh['overhead']:.1%} "
                         f"exceeds the {GATE_OVERHEAD:.0%} gate")


if __name__ == "__main__":
    main()
