"""Decode-latency benchmark — transformer decode through the TMU stack.

One full decoder layer of the phi4-mini smoke model serves prefill plus
``N_DECODE`` incremental decode steps through :class:`DecodeSession`
(position-bucketed ``tm_compile`` via ``TMServer``), measured against the
pure-XLA baseline (the same step functions under plain ``jax.jit``).
Emitted as ``BENCH_decode.json`` (archived per commit by CI):

* **tokens/s** — warm compiled decode vs the jitted XLA loop;
* **per-step TM-phase share** — how much of the decode step's program runs
  as TM phases (instruction share + phase kinds), vs 0% for pure XLA;
* **bit-exact logits** — every step's logits must equal the uncompiled
  (eager) model's bit for bit, prefill included.

Acceptance gates: bit-exact logits on every step, the KV append / RoPE /
head split-merge primitives matched as TM work (no trace fallback for
them), and warm compiled decode at or above ``MIN_TOKENS_PER_S`` — the
floor recorded in the JSON, lenient because the TM stack is a numerical
emulation of the paper's datapath, not a tuned kernel path.

    PYTHONPATH=src python benchmarks/decode_latency.py [--trace out.json]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.compiler import tm_compile
from repro.configs.phi4_mini_3p8b import smoke_config
from repro.models.transformer import init_lm
from repro.obs import as_tracer
from repro.serving import ServerConfig
from repro.serving.decode import DecodeSession

BATCH = 2
PROMPT_LEN = 8
N_DECODE = 32
MAX_LEN = 48
MIN_TOKENS_PER_S = 0.2          # floor for warm compiled decode (see above)
# the decode step's manipulation traffic: these prims must compile to TM
# phases, not fall back to opaque TPU work
REQUIRED_TM_PRIMS = {"dynamic_update_slice",            # KV-cache append
                     "mul", "add", "sub", "concatenate", "slice",  # RoPE
                     "reshape", "transpose"}            # head split/merge


def bench_compiled(cfg, params, prompts, tracer=None) -> dict:
    """Cold pass (per-position compiles) + warm measured pass."""
    # mirror DecodeSession's default config, plus the trace timeline
    srv_cfg = ServerConfig(max_batch=1, batch_timeout_s=0.0,
                           cache_capacity=MAX_LEN + 8, exact=True,
                           trace=tracer)
    with DecodeSession(cfg, params, max_len=MAX_LEN, config=srv_cfg) as sess:
        t0 = time.perf_counter()
        toks_cold, logits_cold = sess.generate(prompts, N_DECODE)
        cold_wall = time.perf_counter() - t0

        t0 = time.perf_counter()
        toks, logits = sess.generate(prompts, N_DECODE)
        warm_wall = time.perf_counter() - t0

        ref_toks, ref_logits = sess.reference_generate(prompts, N_DECODE)
        exact = (bool(jnp.array_equal(toks, ref_toks))
                 and len(logits) == len(ref_logits)
                 and all(bool(jnp.array_equal(a, b))
                         for a, b in zip(logits, ref_logits)))
        snap = sess.server.snapshot_stats()
        session = sess.stats.snapshot()
    tokens = BATCH * N_DECODE
    return {
        "cold_wall_s": cold_wall,
        "warm_wall_s": warm_wall,
        "tokens": tokens,
        "tokens_per_s": tokens / warm_wall,
        "bit_exact_logits": exact,
        "cache": snap["cache"],
        "session": session,
    }


def bench_xla_baseline(cfg, params, prompts) -> dict:
    """The same step functions under plain jax.jit — the pure-XLA loop."""
    with DecodeSession(cfg, params, max_len=MAX_LEN) as sess:
        steps = {p: jax.jit(sess.step_fn(p))
                 for p in [0] + list(range(PROMPT_LEN,
                                           PROMPT_LEN + N_DECODE - 1))}

        def run():
            ck, cv = sess.init_cache(BATCH)
            logits, ck, cv = steps[0](prompts, ck, cv)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            for t in range(N_DECODE - 1):
                logits, ck, cv = steps[PROMPT_LEN + t](tok, ck, cv)
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
                tok = tok.astype(jnp.int32)
            return jax.block_until_ready(tok)

        run()                                   # warm the jit caches
        t0 = time.perf_counter()
        run()
        wall = time.perf_counter() - t0
    tokens = BATCH * N_DECODE
    return {"warm_wall_s": wall, "tokens_per_s": tokens / wall,
            "tm_phase_share": 0.0}


def phase_mix_of_decode_step(cfg, params) -> dict:
    """Compile one decode step standalone and report its TM/TPU split."""
    with DecodeSession(cfg, params, max_len=MAX_LEN) as sess:
        step = sess.step_fn(PROMPT_LEN)
        ck, cv = sess.init_cache(BATCH)
        tok = jnp.zeros((BATCH, 1), jnp.int32)
        compiled = tm_compile(step, tok, ck, cv)
    mix = compiled.partition_report.phase_mix()
    tpu_eqns = sum(len(p.node_indices)
                   for p in compiled.partition_report.phases
                   if p.kind == "tpu")
    total = mix["tmu_instrs"] + tpu_eqns
    matched = set(compiled.matched_prims)
    missing = REQUIRED_TM_PRIMS - matched
    fallback_notes = [str(n) for n in compiled.graph.notes]
    return {
        **mix,
        "tpu_eqns": tpu_eqns,
        "tm_instr_share": mix["tmu_instrs"] / max(total, 1),
        "matched_prims": sorted(matched),
        "missing_required_prims": sorted(missing),
        "fallback_notes": fallback_notes,
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="export the compiled decode pass as Chrome-trace "
                         "JSON (open at https://ui.perfetto.dev)")
    args = ap.parse_args(argv)
    tracer = as_tracer(bool(args.trace))

    cfg = smoke_config()
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (BATCH, PROMPT_LEN), 0, cfg.vocab)

    mix = phase_mix_of_decode_step(cfg, params)
    compiled = bench_compiled(cfg, params, prompts, tracer=tracer)
    baseline = bench_xla_baseline(cfg, params, prompts)

    report = {
        "benchmark": "decode_latency",
        "model": cfg.name,
        "batch": BATCH,
        "prompt_len": PROMPT_LEN,
        "decode_steps": N_DECODE,
        "compiled": compiled,
        "xla_baseline": baseline,
        "decode_step_phase_mix": mix,
        "tokens_per_s_floor": MIN_TOKENS_PER_S,
        "compiled_over_xla": (compiled["tokens_per_s"]
                              / baseline["tokens_per_s"]),
    }

    print("# decode_latency (one phi4-mini layer, prefill + "
          f"{N_DECODE} decode steps, batch {BATCH})")
    print(f"compiled warm: {compiled['tokens_per_s']:.2f} tok/s "
          f"(cold pass {compiled['cold_wall_s']:.1f}s, "
          f"warm {compiled['warm_wall_s']:.1f}s)")
    print(f"pure-XLA jit:  {baseline['tokens_per_s']:.2f} tok/s")
    sess = compiled["session"]
    print(f"per-step latency: p50 {sess['step_latency_p50_s']*1e3:.1f} ms / "
          f"p99 {sess['step_latency_p99_s']*1e3:.1f} ms")
    print(f"TM share of the decode step: {mix['tm_instr_share']:.1%} of "
          f"instructions ({mix['tmu_instrs']} TM / {mix['tpu_eqns']} TPU), "
          f"phases [{mix['kinds']}]")
    print(f"bit-exact logits: {compiled['bit_exact_logits']}")

    with open("BENCH_decode.json", "w") as f:
        json.dump(report, f, indent=2)
    print("\nwrote BENCH_decode.json")
    if args.trace:
        trace = tracer.export_chrome_trace(args.trace)
        print(f"trace: {len(trace['traceEvents'])} events -> {args.trace}")

    if not compiled["bit_exact_logits"]:
        raise SystemExit("served decode logits diverged from the uncompiled "
                         "model (acceptance needs bit-exact)")
    if mix["missing_required_prims"]:
        raise SystemExit(f"decode-step prims not matched as TM work: "
                         f"{mix['missing_required_prims']}")
    if compiled["tokens_per_s"] < MIN_TOKENS_PER_S:
        raise SystemExit(
            f"warm compiled decode at {compiled['tokens_per_s']:.3f} tok/s "
            f"is below the {MIN_TOKENS_PER_S} floor")
    return report


if __name__ == "__main__":
    main()
