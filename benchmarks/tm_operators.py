"""Operator-level TM benchmark — paper Fig. 8 / Table III analogue.

The paper's figure of merit is bandwidth-normalized operator latency: the
TMU wins because it moves exactly the necessary bytes in a memory-to-memory
stream, while CPU/GPU round-trip the cache hierarchy.  The TPU-native
analogue measured here, per operator at (scaled) Table III shapes:

  * standalone — the op as its own jit (input read + output write to "HBM"),
    the unfused baseline every framework pays by default;
  * fused — the op composed into its producer in one jit scope (the
    near-memory execution the TMU performs): marginal latency =
    t(producer∘op) − t(producer);
  * bytes — exact minimal traffic (in+out) vs fused traffic from the fusion
    pass (0 extra for fully-composable ops), the bandwidth-fair metric.

Columns: op, shape, standalone_us, fused_marginal_us, speedup,
bytes_standalone, bytes_fused_extra, traffic_reduction.

``pipeline_rows`` adds the paper's *system-level* figure (Fig. 5 / the 34.6%
e2e claim): the scheduler's cycle model for multi-instruction TM programs,
comparing unpipelined vs double-buffered vs output-forwarded schedules.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core import affine as af
from repro.core import tm_ops
from repro.core.engine import apply_map
from repro.core.instr import EwOp, RMEConfig, TMInstr, TMOpcode, TMProgram
from repro.core.schedule import CycleParams, schedule

# Table III shapes, scaled by `scale` to keep CPU wall times sane.
OPS = [
    ("rearrange", "RR", (448, 448, 3), lambda x: tm_ops.rearrange(x, 1, 16)),
    ("resize", "RS", (448, 448, 3),
     lambda x: tm_ops.resize_bilinear(x, x.shape[0] // 2, x.shape[1] // 2)),
    ("bboxcal", "BC", (448 * 448 // 64, 85),
     lambda x: tm_ops.bboxcal(x, 0.5, 256)[0]),
    ("transpose", "TS", (448, 448, 64), tm_ops.transpose),
    ("rot90", "RT", (448, 448, 64), tm_ops.rot90),
    ("img2col", "IC", (448, 448, 64), lambda x: tm_ops.img2col(x, 3, 3, 1, 0)),
    ("pixelshuffle", "PS", (448, 448, 64), lambda x: tm_ops.pixel_shuffle(x, 2)),
    ("pixelunshuffle", "PU", (448, 448, 64),
     lambda x: tm_ops.pixel_unshuffle(x, 2)),
    ("upsample", "US", (448, 448, 64), lambda x: tm_ops.upsample(x, 2)),
    ("route", "RO", (448, 448, 64), None),   # two-input
    ("split", "SL", (448, 448, 64), lambda x: tm_ops.split(x, 2)[0]),
    ("add", "AD", (448, 448, 64), None),     # two-input
]


def _scaled(shape, scale):
    def r8(v):  # round to a multiple of 8 (divisibility for s=2 ops)
        return max(8, int(v * scale) // 8 * 8)

    if len(shape) == 3:
        h, w, c = shape
        return (r8(h), r8(w), c)
    return (max(64, int(shape[0] * scale * scale)), shape[1])


def run(scale: float = 0.25, reps: int = 5):
    rows = []
    producer = lambda x: x * 1.0001 + 0.5  # stand-in for the upstream op

    for name, abbr, shape, fn in OPS:
        shp = _scaled(shape, scale)
        x = jnp.asarray(np.random.RandomState(0).rand(*shp).astype(np.float32))
        if name == "route":
            fn1 = lambda a: tm_ops.route([a, a])
        elif name == "add":
            fn1 = lambda a: tm_ops.add(a, a)
        else:
            fn1 = fn

        standalone = jax.jit(fn1)
        t_stand = time_fn(standalone, x, reps=reps)

        fused = jax.jit(lambda a: fn1(producer(a)))
        prod_only = jax.jit(producer)
        t_fused_total = time_fn(fused, x, reps=reps)
        t_prod = time_fn(prod_only, x, reps=reps)
        t_marginal = max(t_fused_total - t_prod, 1e-9)

        in_bytes = x.size * 4 * (2 if name in ("route", "add") else 1)
        out = jax.eval_shape(fn1, x)
        out_bytes = sum(math.prod(o.shape) * o.dtype.itemsize
                        for o in jax.tree.leaves(out))
        stand_bytes = in_bytes + out_bytes
        # fused extra traffic: 0 when the map composes into the producer
        # (everything except the data-dependent fine-grained ops)
        fused_extra = 0 if name not in ("bboxcal", "resize") else out_bytes
        rows.append({
            "op": name, "abbr": abbr, "shape": "x".join(map(str, shp)),
            "standalone_us": t_stand * 1e6,
            "fused_marginal_us": t_marginal * 1e6,
            "speedup": t_stand / t_marginal,
            "bytes_standalone": stand_bytes,
            "bytes_fused_extra": fused_extra,
            "traffic_reduction": 1 - fused_extra / stand_bytes,
        })
    return rows


# ---------------------------------------------------------------------------
# pipeline-schedule benchmark (double buffering + output forwarding)
# ---------------------------------------------------------------------------

def _superres_tail(H: int, W: int, C: int) -> tuple[TMProgram, dict]:
    """EDSR-style tail: transpose -> pixel-shuffle -> residual add."""
    m1 = af.transpose_map((H, W, C))
    m2 = af.pixel_shuffle_map((W, H, C), 2)
    m3 = af.identity_map((W * 2, H * 2, C // 4))
    prog = TMProgram(
        [TMInstr(TMOpcode.COARSE, ("x",), "t", map_=m1),
         TMInstr(TMOpcode.COARSE, ("t",), "up", map_=m2),
         TMInstr(TMOpcode.COARSE, ("up", "skip"), "y", map_=m3, ew=EwOp.ADD)],
        inputs=("x", "skip"), outputs=("y",))
    return prog, {"x": (H, W, C), "skip": (W * 2, H * 2, C // 4)}


def _detect_tail(H: int, W: int, C: int, cap: int) -> tuple[TMProgram, dict]:
    """YOLO-style tail: rearrange -> img2col-format head -> bboxcal filter."""
    m1 = af.rearrange_map((H, W * 4, C), 4, 2 * C * 4)
    pred_rows = H * W
    m2 = af.MixedRadixMap(
        out_shape=(pred_rows, 2 * C * 4), in_shape=(H, W, 2 * C * 4),
        splits=(af.DigitSplit(0, W),),
        affine=af.AffineMap.make([[1, 0, 0], [0, 0, 1], [0, 1, 0]]))
    prog = TMProgram(
        [TMInstr(TMOpcode.COARSE, ("img",), "re", map_=m1),
         TMInstr(TMOpcode.COARSE, ("re",), "pred", map_=m2),
         TMInstr(TMOpcode.FINE_EVALUATE, ("pred",), "boxes",
                 rme=RMEConfig(scheme="evaluate", threshold=0.5, cmp="ge",
                               score_index=4, capacity=cap))],
        inputs=("img",), outputs=("boxes",))
    return prog, {"img": (H, W * 4, C)}


PIPELINES = [
    ("superres_tail", lambda s: _superres_tail(
        max(32, int(448 * s) // 16 * 16), max(32, int(448 * s) // 16 * 16), 16)),
    ("detect_tail", lambda s: _detect_tail(
        max(16, int(448 * s) // 16 * 16), max(16, int(448 * s) // 16 * 16),
        3, 256)),
]


def pipeline_rows(scale: float = 0.25,
                  params: CycleParams | None = None) -> list[dict]:
    rows = []
    for name, mk in PIPELINES:
        prog, shapes = mk(scale)
        rep = schedule(prog, shapes, params)
        rows.append({
            "program": name, "n_instr": len(prog.instrs),
            "forwards": len(rep.forwards),
            "unpipelined": rep.unpipelined_cycles,
            "double_buffered": rep.pipelined_cycles,
            "forwarded": rep.forwarded_cycles,
            "db_speedup": rep.double_buffer_speedup,
            "pipeline_speedup": rep.pipeline_speedup,
            "latency_reduction": 1 - rep.forwarded_cycles / rep.unpipelined_cycles,
        })
    return rows


def pipeline_main(scale: float = 0.25) -> list[dict]:
    rows = pipeline_rows(scale=scale)
    print("# tm_pipeline (double buffering + output forwarding cycle model)")
    print(f"{'program':16s}{'instrs':>7s}{'fwd':>5s}{'unpiped':>12s}"
          f"{'dbuf':>12s}{'fwded':>12s}{'speedup':>9s}{'e2e_red':>9s}")
    for r in rows:
        print(f"{r['program']:16s}{r['n_instr']:>7d}{r['forwards']:>5d}"
              f"{r['unpipelined']:>12.0f}{r['double_buffered']:>12.0f}"
              f"{r['forwarded']:>12.0f}{r['pipeline_speedup']:>9.2f}"
              f"{r['latency_reduction']:>9.2%}")
    return rows


def main(scale: float = 0.25):
    rows = run(scale=scale)
    print("# tm_operators (Fig. 8 / Table III analogue), scale=%.2f" % scale)
    print(f"{'op':16s}{'shape':>16s}{'standalone_us':>15s}"
          f"{'fused_us':>12s}{'speedup':>9s}{'traffic_red':>12s}")
    for r in rows:
        print(f"{r['op']:16s}{r['shape']:>16s}{r['standalone_us']:>15.1f}"
              f"{r['fused_marginal_us']:>12.1f}{r['speedup']:>9.2f}"
              f"{r['traffic_reduction']:>12.2%}")
    print()
    pipeline_main(scale=scale)
    return rows


if __name__ == "__main__":
    main()
