"""Operator-level TM benchmark — paper Fig. 8 / Table III analogue.

The paper's figure of merit is bandwidth-normalized operator latency: the
TMU wins because it moves exactly the necessary bytes in a memory-to-memory
stream, while CPU/GPU round-trip the cache hierarchy.  The TPU-native
analogue measured here, per operator at (scaled) Table III shapes:

  * standalone — the op as its own jit (input read + output write to "HBM"),
    the unfused baseline every framework pays by default;
  * fused — the op composed into its producer in one jit scope (the
    near-memory execution the TMU performs): marginal latency =
    t(producer∘op) − t(producer);
  * bytes — exact minimal traffic (in+out) vs fused traffic from the fusion
    pass (0 extra for fully-composable ops), the bandwidth-fair metric.

Columns: op, shape, standalone_us, fused_marginal_us, speedup,
bytes_standalone, bytes_fused_extra, traffic_reduction.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core import affine as af
from repro.core import tm_ops
from repro.core.engine import apply_map

# Table III shapes, scaled by `scale` to keep CPU wall times sane.
OPS = [
    ("rearrange", "RR", (448, 448, 3), lambda x: tm_ops.rearrange(x, 1, 16)),
    ("resize", "RS", (448, 448, 3),
     lambda x: tm_ops.resize_bilinear(x, x.shape[0] // 2, x.shape[1] // 2)),
    ("bboxcal", "BC", (448 * 448 // 64, 85),
     lambda x: tm_ops.bboxcal(x, 0.5, 256)[0]),
    ("transpose", "TS", (448, 448, 64), tm_ops.transpose),
    ("rot90", "RT", (448, 448, 64), tm_ops.rot90),
    ("img2col", "IC", (448, 448, 64), lambda x: tm_ops.img2col(x, 3, 3, 1, 0)),
    ("pixelshuffle", "PS", (448, 448, 64), lambda x: tm_ops.pixel_shuffle(x, 2)),
    ("pixelunshuffle", "PU", (448, 448, 64),
     lambda x: tm_ops.pixel_unshuffle(x, 2)),
    ("upsample", "US", (448, 448, 64), lambda x: tm_ops.upsample(x, 2)),
    ("route", "RO", (448, 448, 64), None),   # two-input
    ("split", "SL", (448, 448, 64), lambda x: tm_ops.split(x, 2)[0]),
    ("add", "AD", (448, 448, 64), None),     # two-input
]


def _scaled(shape, scale):
    def r8(v):  # round to a multiple of 8 (divisibility for s=2 ops)
        return max(8, int(v * scale) // 8 * 8)

    if len(shape) == 3:
        h, w, c = shape
        return (r8(h), r8(w), c)
    return (max(64, int(shape[0] * scale * scale)), shape[1])


def run(scale: float = 0.25, reps: int = 5):
    rows = []
    producer = lambda x: x * 1.0001 + 0.5  # stand-in for the upstream op

    for name, abbr, shape, fn in OPS:
        shp = _scaled(shape, scale)
        x = jnp.asarray(np.random.RandomState(0).rand(*shp).astype(np.float32))
        if name == "route":
            fn1 = lambda a: tm_ops.route([a, a])
        elif name == "add":
            fn1 = lambda a: tm_ops.add(a, a)
        else:
            fn1 = fn

        standalone = jax.jit(fn1)
        t_stand = time_fn(standalone, x, reps=reps)

        fused = jax.jit(lambda a: fn1(producer(a)))
        prod_only = jax.jit(producer)
        t_fused_total = time_fn(fused, x, reps=reps)
        t_prod = time_fn(prod_only, x, reps=reps)
        t_marginal = max(t_fused_total - t_prod, 1e-9)

        in_bytes = x.size * 4 * (2 if name in ("route", "add") else 1)
        out = jax.eval_shape(fn1, x)
        out_bytes = sum(math.prod(o.shape) * o.dtype.itemsize
                        for o in jax.tree.leaves(out))
        stand_bytes = in_bytes + out_bytes
        # fused extra traffic: 0 when the map composes into the producer
        # (everything except the data-dependent fine-grained ops)
        fused_extra = 0 if name not in ("bboxcal", "resize") else out_bytes
        rows.append({
            "op": name, "abbr": abbr, "shape": "x".join(map(str, shp)),
            "standalone_us": t_stand * 1e6,
            "fused_marginal_us": t_marginal * 1e6,
            "speedup": t_stand / t_marginal,
            "bytes_standalone": stand_bytes,
            "bytes_fused_extra": fused_extra,
            "traffic_reduction": 1 - fused_extra / stand_bytes,
        })
    return rows


def main(scale: float = 0.25):
    rows = run(scale=scale)
    print("# tm_operators (Fig. 8 / Table III analogue), scale=%.2f" % scale)
    print(f"{'op':16s}{'shape':>16s}{'standalone_us':>15s}"
          f"{'fused_us':>12s}{'speedup':>9s}{'traffic_red':>12s}")
    for r in rows:
        print(f"{r['op']:16s}{r['shape']:>16s}{r['standalone_us']:>15.1f}"
              f"{r['fused_marginal_us']:>12.1f}{r['speedup']:>9.2f}"
              f"{r['traffic_reduction']:>12.2%}")
    return rows


if __name__ == "__main__":
    main()
