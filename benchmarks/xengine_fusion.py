"""Cross-engine megakernel benchmark — TM chains fused into compute launches.

The cross-engine fusion's acceptance measurement: a superres block (1x1
conv head -> depth-to-space tail, the paper's Table III shapes scaled for
the interpret-mode harness) is compiled twice over identical graphs —

* **split** — ``tm_compile(block, x)``: the PR-4 execution model.  The
  final matmul runs as a jitted XLA computation, its output round-trips
  through HBM, and the TM tail runs as one chained Pallas launch;
* **fused** — ``tm_compile(block, x, cross_engine=True)``: the partition
  merges the legal engine crossing into ONE ``fused`` phase that lowers as
  a single Pallas launch (``pallas.xchain.commit``) — the matmul's output
  slab stays in VMEM and the chain gathers stream straight out of it.

Emits ``BENCH_xengine.json`` (best of ``N_RUNS`` paired alternating
rounds per path, realized launch and HBM-byte accounting per request, and
the yolov3_tiny end-to-end crossing count).

Acceptance gates (CI):

* the fused program must execute **strictly fewer kernel launches** per
  request than the split program (counted from the realized phase
  reports, not the model);
* the fused program must move **strictly fewer HBM bytes** per request
  (the crossing buffer and the chain's internal segments never
  materialize);
* outputs must be **bit-exact** vs the split path;
* the crossing must be **realized** — at least one ``pallas.xchain``
  lowering record in the fused run;
* yolov3_tiny must compile with at least one realized crossing and fewer
  launches than its PR-4 chained partition;
* wall clock: best-vs-best over alternating-order rounds (the
  ``trace_gate`` discipline — see benchmarks/pipeline_overlap.py for why
  best-of-N is the only estimator tight enough for a fixed-ratio gate).

The wall gate is parallelism-aware, same regime split as
``pipeline_overlap.py``: the fused launch wins by eliding dispatch and
HBM round-trips, but under interpret-mode Pallas (this CI harness) every
operand block is copied once per grid step, so moving the matmul from an
XLA computation into the interpreted kernel trades compiled-matmul FLOPs
for interpreter bytes.  On a >= 2-core host the gate demands the full
``GATE_SPEEDUP``; on a single-core host (where the interpreter tax has no
parallel slack to hide in) the gate degrades to the dispatch-overhead
floor ``GATE_SPEEDUP_SINGLE_CORE`` — fusion must not collapse throughput
— and the applied regime is recorded in the JSON.

    PYTHONPATH=src python benchmarks/xengine_fusion.py
"""

from __future__ import annotations

import json
import os
import statistics
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.compiler.api import TPUPhaseReport, tm_compile
from repro.core.schedule import CycleParams
from repro.models import cnn

GATE_SPEEDUP = 1.2               # >= 2 cores: the fusion win must be real
GATE_SPEEDUP_SINGLE_CORE = 0.75  # 1 core: dispatch-overhead floor only
N_RUNS = 8                 # paired rounds per path (even: alternating
                           # within-round order stays balanced)
N_REQUESTS = 6             # per measured pass
SHAPE = (1, 48, 48, 3)     # superres input (B, H, W, C)
C_MID = 192                # conv-head width
C_OUT = 32                 # head output channels (s*s*c for the shuffle)
SEGMENT_BYTES = 1 << 18    # pinned segment budget (larger slabs amortize
                           # the per-grid-step interpreter copies)

_ks = jax.random.split(jax.random.PRNGKey(0), 3)
_W0 = jax.random.normal(_ks[0], (3, C_MID), jnp.float32) * 3 ** -0.5
_W1 = jax.random.normal(_ks[1], (C_MID, C_MID), jnp.float32) * C_MID ** -0.5
_W2 = jax.random.normal(_ks[2], (C_MID, C_OUT), jnp.float32) * C_MID ** -0.5


def superres_block(x):
    """1x1 conv head -> TM border crop -> 1x1 projection -> superres tail
    (depth-to-space, crop, re-pad).

    The mid-block crop puts the projection einsum in a TPU phase of its
    own, input already HBM-resident — the realistic crossing shape: a
    compute kernel sandwiched between TM runs.  Its output feeds exactly
    one consumer — the tail's layout chain — so ``cross_engine=True``
    merges matmul + tail into ONE fused phase: one launch replaces the
    split path's jit call + chain kernel, and the crossing buffer never
    touches HBM."""
    h = jax.nn.relu(jnp.einsum("bhwc,co->bhwo", x, _W0))
    h = jax.nn.relu(jnp.einsum("bhwc,co->bhwo", h, _W1))
    h = jax.lax.slice(h, (0, 1, 1, 0),
                      (1, SHAPE[1] - 1, SHAPE[2] - 1, C_MID))
    h = jnp.einsum("bhwc,co->bhwo", h, _W2)
    B, H, W, C = h.shape
    s = 2
    c = C // (s * s)
    t = h.reshape(B, H, W, s, s, c)
    t = jnp.transpose(t, (0, 1, 3, 2, 4, 5))
    t = t.reshape(B, H * s, W * s, c)
    t = jax.lax.slice(t, (0, s, s, 0), (B, H * s - s, W * s - s, c))
    return jnp.pad(t, ((0, 0), (1, 1), (1, 1), (0, 0)))


def run_counted(compiled, args):
    """One request, phase by phase; returns (outputs, realized launches,
    xchain record count).  A TPU phase's jitted callable is one XLA
    computation = one launch; a TM/fused phase reports its own Pallas
    launch count (a chained run is one launch per chain, a fused phase one
    launch for the whole crossing)."""
    env = compiled.bind_inputs(*args)
    launches = 0
    xchain = 0
    for phase in compiled.partition_report.phases:
        rep = compiled.run_phase(phase, env, backend="pallas",
                                 fuse_chains=True)
        if isinstance(rep, TPUPhaseReport):
            launches += rep.xla_computations
        else:
            launches += rep.launch_count()
            xchain += sum(1 for r in rep.records
                          if (r.path or "").startswith("pallas.xchain"))
    return compiled.outputs_from(env), launches, xchain


def hbm_bytes(compiled) -> int:
    """Modeled HBM traffic of one request: every phase's external reads and
    downstream-visible writes.  Fused phases exclude the crossing buffer
    and the chain's internal segments — they never leave VMEM."""
    return sum(compiled._phase_hbm_bytes(p)
               for p in compiled.partition_report.phases)


def bench_wall(compiled, reqs) -> float:
    t0 = time.perf_counter()
    for args in reqs:
        out, _ = compiled.run(*args, backend="pallas", fuse_chains=True)
        jax.block_until_ready(out)
    return time.perf_counter() - t0


def yolo_section() -> dict:
    """yolov3_tiny end to end: backbone + neck through cross_engine=True
    must realize at least one crossing and launch strictly less than the
    PR-4 chained partition of the same graph."""
    p = cnn.init_yolov3_tiny(jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (1, 32, 32, 3),
                           jnp.float32)
    params = CycleParams(segment_bytes=SEGMENT_BYTES)
    fn = lambda img: cnn.yolov3_tiny(p, img)
    base = tm_compile(fn, x, params=params)
    fused = tm_compile(fn, x, params=params, cross_engine=True)
    _, base_launches, _ = run_counted(base, (x,))
    out, fused_launches, xchain = run_counted(fused, (x,))
    jax.block_until_ready(out)
    want = jax.block_until_ready(fn(x))
    close = all(bool(np.allclose(np.asarray(a), np.asarray(b),
                                 rtol=1e-3, atol=1e-3))
                for a, b in zip(jax.tree_util.tree_leaves(out),
                                jax.tree_util.tree_leaves(want)))
    return {
        "xengine_phases": fused.partition_report.xengine_phases,
        "realized_crossings": xchain,
        "launches_split": base_launches,
        "launches_fused": fused_launches,
        "phases_split": len(base.partition_report.phases),
        "phases_fused": len(fused.partition_report.phases),
        "allclose": close,
    }


def main() -> None:
    rng = np.random.RandomState(0)
    params = CycleParams(segment_bytes=SEGMENT_BYTES)
    x0 = jnp.asarray(rng.rand(*SHAPE).astype(np.float32))

    split = tm_compile(superres_block, x0, params=params)
    fused = tm_compile(superres_block, x0, params=params, cross_engine=True)

    # --- structural gates: launches, HBM, realization, parity -------------
    split_out, split_launches, _ = run_counted(split, (x0,))
    fused_out, fused_launches, xchain = run_counted(fused, (x0,))
    exact = bool(np.array_equal(np.asarray(split_out),
                                np.asarray(fused_out)))
    split_hbm = hbm_bytes(split)
    fused_hbm = hbm_bytes(fused)

    # --- wall: best-of-N paired alternating rounds ------------------------
    split_walls, fused_walls = [], []
    for i in range(N_RUNS):
        reqs = [(jnp.asarray(rng.rand(*SHAPE).astype(np.float32)),)
                for _ in range(N_REQUESTS)]
        passes = [("split", lambda: bench_wall(split, reqs)),
                  ("fused", lambda: bench_wall(fused, reqs))]
        if i % 2:
            passes.reverse()
        for tag, run in passes:
            (split_walls if tag == "split" else fused_walls).append(run())

    split_best, fused_best = min(split_walls), min(fused_walls)
    speedup = split_best / fused_best
    split_med = statistics.median(split_walls)
    fused_med = statistics.median(fused_walls)
    cpu_count = os.cpu_count() or 1
    gate = GATE_SPEEDUP if cpu_count >= 2 else GATE_SPEEDUP_SINGLE_CORE
    yolo = yolo_section()

    result = {
        "workload": {
            "block": "superres (1x1 conv head + depth-to-space tail)",
            "shape": SHAPE,
            "c_mid": C_MID,
            "c_out": C_OUT,
            "segment_bytes": SEGMENT_BYTES,
            "requests_per_run": N_REQUESTS,
            "runs": N_RUNS,
        },
        "phases_split": split.partition_report.phase_mix()["kinds"],
        "phases_fused": fused.partition_report.phase_mix()["kinds"],
        "xengine_phases": fused.partition_report.xengine_phases,
        "xengine_saved_bytes_modeled":
            fused.partition_report.xengine_saved_bytes,
        "launches_split": split_launches,
        "launches_fused": fused_launches,
        "realized_crossings": xchain,
        "hbm_bytes_split": split_hbm,
        "hbm_bytes_fused": fused_hbm,
        "bit_exact": exact,
        "split_wall_s": split_best,
        "fused_wall_s": fused_best,
        "split_wall_s_median": split_med,
        "fused_wall_s_median": fused_med,
        "split_wall_s_runs": split_walls,
        "fused_wall_s_runs": fused_walls,
        "speedup": speedup,
        "speedup_median": split_med / fused_med,
        "cpu_count": cpu_count,
        "gate_speedup": gate,
        "gate_regime": "parallel" if cpu_count >= 2 else "single-core",
        "yolov3_tiny": yolo,
    }
    with open("BENCH_xengine.json", "w") as f:
        json.dump(result, f, indent=2)

    print(f"phases: split {result['phases_split']} -> "
          f"fused {result['phases_fused']} "
          f"({result['xengine_phases']} crossing(s))")
    print(f"launches/request: split {split_launches} -> "
          f"fused {fused_launches} "
          f"({xchain} realized pallas.xchain launch(es))")
    print(f"hbm bytes/request: split {split_hbm} -> fused {fused_hbm} "
          f"({split_hbm - fused_hbm} elided)")
    print(f"split (best of {N_RUNS}): {split_best * 1e3:8.1f} ms "
          f"/ {N_REQUESTS} requests (median {split_med * 1e3:.1f} ms)")
    print(f"fused (best of {N_RUNS}): {fused_best * 1e3:8.1f} ms "
          f"/ {N_REQUESTS} requests (median {fused_med * 1e3:.1f} ms)")
    print(f"speedup: {speedup:.2f}x best-vs-best (gate >= {gate}x "
          f"[{result['gate_regime']}, {cpu_count} core(s)]; "
          f"median {split_med / fused_med:.2f}x)")
    print(f"bit-exact vs split: {exact}")
    print(f"yolov3_tiny: {yolo['xengine_phases']} crossing(s), "
          f"{yolo['realized_crossings']} realized; launches "
          f"{yolo['launches_split']} -> {yolo['launches_fused']}; "
          f"allclose {yolo['allclose']}")
    if cpu_count < 2:
        print("note: single-core host — interpret-mode Pallas pays a "
              "per-grid-step operand copy the fused matmul cannot hide "
              "without parallel slack; gating dispatch overhead only")

    if xchain < 1:
        raise SystemExit("FAIL: no realized pallas.xchain launch")
    if not exact:
        raise SystemExit("FAIL: fused output diverged from split")
    if fused_launches >= split_launches:
        raise SystemExit(f"FAIL: fused launches {fused_launches} not "
                         f"strictly under split {split_launches}")
    if fused_hbm >= split_hbm:
        raise SystemExit(f"FAIL: fused HBM bytes {fused_hbm} not strictly "
                         f"under split {split_hbm}")
    if yolo["xengine_phases"] < 1 or yolo["realized_crossings"] < 1:
        raise SystemExit("FAIL: yolov3_tiny realized no crossing")
    if yolo["launches_fused"] >= yolo["launches_split"]:
        raise SystemExit("FAIL: yolov3_tiny fused launches not reduced")
    if not yolo["allclose"]:
        raise SystemExit("FAIL: yolov3_tiny fused output diverged")
    if speedup < gate:
        raise SystemExit(f"FAIL: fused speedup {speedup:.2f}x under the "
                         f"{gate}x gate")
    print("PASS")


if __name__ == "__main__":
    main()
