"""Quickstart: the TM layer in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the paper's three core ideas:
  1. one reconfigurable engine executes every TM operator ((A,B) registers);
  2. near-memory execution = fusion: chained ops compose into one pass;
  3. the same maps drive a real Pallas TPU kernel (validated in interpret
     mode here; BlockSpec index_maps are the address generator on TPU).
"""

import numpy as np
import jax.numpy as jnp

from repro.core import affine as af, tm_ops
from repro.core.executor import TMExecutor
from repro.core.instr import TMInstr, TMOpcode, TMProgram
from repro.kernels.tm_affine import plan_of, tm_affine_call

# -- 1. functional TM ops (all backed by ONE engine) -------------------------
x = jnp.arange(4 * 6 * 8, dtype=jnp.float32).reshape(4, 6, 8)
print("transpose:", tm_ops.transpose(x).shape)
print("pixel_shuffle:", tm_ops.pixel_shuffle(x, 2).shape)
print("img2col:", tm_ops.img2col(x, 3, 3, 1, 1).shape)

# -- 2. a TM *program* (the TMU instruction stream) + fusion -----------------
prog = TMProgram(
    instrs=[
        TMInstr(TMOpcode.COARSE, ("x",), "t", map_=af.transpose_map((4, 6, 8))),
        TMInstr(TMOpcode.COARSE, ("t",), "y", map_=af.split_map((6, 4, 8), 2, 1)),
    ],
    inputs=("x",), outputs=("y",),
)
ex = TMExecutor(backend="fused")
y = ex(prog, {"x": x})["y"]
print(f"fused program: {ex.last_report.fused_pairs} pair fused, "
      f"traffic -{ex.last_report.traffic_reduction:.0%} "
      f"(near-memory execution)")

# -- 3. the same map as a Pallas TPU kernel ----------------------------------
m = af.rot90_map((64, 128, 8))
xb = jnp.arange(64 * 128 * 8, dtype=jnp.float32).reshape(64, 128, 8)
out = tm_affine_call(xb, m, interpret=True)
assert np.array_equal(np.asarray(out), np.rot90(np.asarray(xb), axes=(0, 1)))
print(f"pallas rot90: mode={'block (pure DMA readdressing)' if plan_of(m) else 'gather'}, OK")

# -- 4. reconfigurability: a brand-new op is just new register values --------
rot180 = af.MixedRadixMap(
    out_shape=(64, 128, 8), in_shape=(64, 128, 8), splits=(),
    affine=af.AffineMap.make([[-1, 0, 0], [0, -1, 0], [0, 0, 1]], [63, 127, 0]))
out = tm_affine_call(xb, rot180, interpret=True)
assert np.array_equal(np.asarray(out), np.asarray(xb)[::-1, ::-1, :])
print("new op rot180: zero new datapath code, OK")
