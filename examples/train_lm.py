"""End-to-end training driver: decoder LM on the synthetic pipeline with
checkpointing, heartbeat, straggler detection and (optional) int8 gradient
compression.

Default is a CPU-sized model; ``--params 100m --steps 300`` reproduces the
deliverable-scale run on accelerator hardware (also runs on CPU, slowly).

    PYTHONPATH=src python examples/train_lm.py --steps 100
    PYTHONPATH=src python examples/train_lm.py --params 100m --steps 300
"""

import argparse

import jax.numpy as jnp

from repro.launch.train import train
from repro.models.transformer import ModelConfig

SIZES = {
    "2m": dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
               vocab=2048),
    "20m": dict(n_layers=8, d_model=384, n_heads=6, n_kv_heads=2, d_ff=1536,
                vocab=8192),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=3072, vocab=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", default="2m", choices=list(SIZES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = ModelConfig(name=f"lm-{args.params}", family="dense",
                      dtype=jnp.float32, remat="none", **SIZES[args.params])
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq}")
    _, losses = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                      ckpt_dir=args.ckpt, ckpt_every=max(args.steps // 4, 1),
                      peak_lr=args.lr, compress=args.compress)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
