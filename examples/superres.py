"""Super-resolution (the paper's flagship application, EDSR/ESPCN) with the
two TMU system-level tricks made visible:

  * near-memory fusion — the whole network in one jit vs per-op execution;
  * output forwarding — the final projection's PixelShuffle applied at
    matmul tile-commit time by the Pallas ``matmul_tm`` kernel (paper
    Fig. 5c), validated against the unfused reference.

    PYTHONPATH=src python examples/superres.py
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.matmul_tm import (matmul_pixel_shuffle_call,
                                     matmul_pixel_shuffle_ref)
from repro.models import cnn


def main():
    key = jax.random.PRNGKey(0)
    img = jax.random.uniform(key, (1, 64, 64, 3))

    # -- EDSR end to end ------------------------------------------------
    p = cnn.init_edsr(key, n_blocks=4, s=2)
    fused = jax.jit(lambda x: cnn.edsr(p, x))
    out = jax.block_until_ready(fused(img))
    t0 = time.perf_counter()
    for _ in range(3):
        out = jax.block_until_ready(fused(img))
    t = (time.perf_counter() - t0) / 3
    print(f"EDSR x2: {img.shape} -> {out.shape}  ({t*1e3:.1f} ms fused)")

    # -- output forwarding: PixelShuffle at matmul tile commit ----------
    H, W, C, s, K = 16, 32, 3, 2, 64
    feats = jax.random.normal(key, (H * W, K))          # last-layer features
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, C * s * s)) * 0.1
    y_fwd = matmul_pixel_shuffle_call(feats, w, H=H, W=W, C=C, s=s)
    y_ref = matmul_pixel_shuffle_ref(feats, w, H, W, C, s)
    assert np.allclose(np.asarray(y_fwd), np.asarray(y_ref), atol=1e-4)
    print(f"output forwarding: matmul -> ({H*s}, {W*s}, {C}) image written "
          f"directly at tile commit (0 extra HBM round-trips), matches ref")


if __name__ == "__main__":
    main()
