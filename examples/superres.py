"""Super-resolution (the paper's flagship application, EDSR/ESPCN) with the
TMU system-level tricks made visible:

  * near-memory fusion — the whole network in one jit vs per-op execution;
  * output forwarding — the final projection's PixelShuffle applied at
    matmul tile-commit time by the Pallas ``matmul_tm`` kernel (paper
    Fig. 5c), validated against the unfused reference;
  * the compiler — ``tm_compile`` lowers the plain-jax tail into a
    scheduled TMProgram (map-composition fusion + epilogue sinking +
    output forwarding), printing the pass pipeline it ran.

    PYTHONPATH=src python examples/superres.py
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.compiler import tm_compile
from repro.kernels.matmul_tm import (matmul_pixel_shuffle_call,
                                     matmul_pixel_shuffle_ref)
from repro.models import cnn


def main():
    key = jax.random.PRNGKey(0)
    img = jax.random.uniform(key, (1, 64, 64, 3))

    # -- EDSR end to end ------------------------------------------------
    p = cnn.init_edsr(key, n_blocks=4, s=2)
    fused = jax.jit(lambda x: cnn.edsr(p, x))
    out = jax.block_until_ready(fused(img))
    t0 = time.perf_counter()
    for _ in range(3):
        out = jax.block_until_ready(fused(img))
    t = (time.perf_counter() - t0) / 3
    print(f"EDSR x2: {img.shape} -> {out.shape}  ({t*1e3:.1f} ms fused)")

    # -- output forwarding: PixelShuffle at matmul tile commit ----------
    H, W, C, s, K = 16, 32, 3, 2, 64
    feats = jax.random.normal(key, (H * W, K))          # last-layer features
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, C * s * s)) * 0.1
    y_fwd = matmul_pixel_shuffle_call(feats, w, H=H, W=W, C=C, s=s)
    y_ref = matmul_pixel_shuffle_ref(feats, w, H, W, C, s)
    assert np.allclose(np.asarray(y_fwd), np.asarray(y_ref), atol=1e-4)
    print(f"output forwarding: matmul -> ({H*s}, {W*s}, {C}) image written "
          f"directly at tile commit (0 extra HBM round-trips), matches ref")

    # -- the compiler: plain jax -> scheduled TMProgram -----------------
    print("\n== tm_compile(superres_tail) ==")
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.rand(2, 32, 32, 32).astype(np.float32))
    skip = jnp.asarray(rng.rand(2, 64, 64, 8).astype(np.float32))
    compiled = tm_compile(cnn.superres_tail, x, skip)
    print(compiled.report())
    ref = cnn.superres_tail(x, skip)
    for backend in ("reference", "fused", "pallas"):
        got = compiled(x, skip, backend=backend)
        assert np.array_equal(np.asarray(got), np.asarray(ref)), backend
    pr = compiled.partition_report
    print(f"compiled tail bit-exact on all 3 backends; cycle model "
          f"{pr.unpipelined_cycles:.0f} -> {pr.forwarded_cycles:.0f} "
          f"({pr.latency_reduction:.1%} e2e latency reduction)")


if __name__ == "__main__":
    main()
