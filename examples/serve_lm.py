"""End-to-end serving driver (the paper's kind is inference): batched
requests against a small LM — prefill + decode with KV cache, measuring
per-phase latency and tokens/s.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2-moe-a2.7b
    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-3b --gen 64
"""

import argparse

from repro.configs import get_smoke, list_archs
from repro.launch.serve import serve
from repro.obs import as_tracer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="export a Chrome-trace span timeline of the run")
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    print(f"serving {cfg.name} ({cfg.family}), batch={args.batch}, "
          f"prompt={args.prompt_len}, gen={args.gen}")
    tracer = as_tracer(bool(args.trace))
    toks, stats = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                        gen=args.gen, tracer=tracer)
    if args.trace:
        trace = tracer.export_chrome_trace(args.trace)
        print(f"trace: {len(trace['traceEvents'])} events -> {args.trace}")
    if stats.get("prefill_only"):
        print(f"prefill: {stats['prefill_s']*1e3:.1f} ms | "
              f"{stats['tokens_per_s']:.1f} prompt tok/s (prefill-only)")
    else:
        print(f"prefill: {stats['prefill_s']*1e3:.1f} ms | "
              f"decode: {stats['decode_s']*1e3:.1f} ms | "
              f"{stats['tokens_per_s']:.1f} tok/s")
        print("sample:", toks[0][:12].tolist())


if __name__ == "__main__":
    main()
